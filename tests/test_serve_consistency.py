"""Serving correctness: step-by-step decode through the KV cache must
reproduce the teacher-forced full-sequence forward — per architecture.
This is the strongest cache-correctness check there is: one off-by-one in
ring-buffer indexing, masks, rope positions, SSM state or cross-attention
and the logits diverge.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry, vlm_stub

# archs whose reduced configs exercise every distinct cache type:
# GQA global, local ring buffer, MLA latent, SSM state, RG-LRU state,
# enc-dec cross cache, vlm prefix.
ARCHS = [
    "smollm-135m",          # plain GQA
    "gemma2-2b",            # local+global ring buffer + softcaps
    "qwen3-4b",             # qk-norm
    "deepseek-v2-236b",     # MLA latent cache + MoE
    "qwen2-moe-a2.7b",      # MoE shared+routed
    "mamba2-130m",          # SSM state + conv cache
    "recurrentgemma-9b",    # RG-LRU + local attn
    "whisper-base",         # enc-dec cross cache
    "llava-next-mistral-7b" # vision prefix
]


def _tol(arch):
    # fp32 reduced configs; recurrences accumulate a bit more error
    return dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forced_forward(arch):
    cfg = configs.get_config(arch, reduced=True)
    task = registry.make_task(cfg)
    key = jax.random.PRNGKey(0)
    params = task.init(key)

    B, Lp, Lgen = 2, 8, 6
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    full_tokens = jax.random.randint(
        ks[0], (B, Lp + Lgen), 0, cfg.vocab_size).astype(jnp.int32)

    extra = {}
    n_vis = cfg.vision_tokens
    if n_vis:
        extra["patch_embeds"] = vlm_stub.synthetic_patch_embeds(
            ks[1], B, n_vis, cfg.d_model, cfg.dtype)
    if cfg.encoder_decoder:
        frames = jax.random.normal(
            ks[2], (B, 16, cfg.d_model)).astype(cfg.dtype)

    # ---- teacher-forced full forward over Lp + Lgen tokens
    if cfg.encoder_decoder:
        memory = task.model.encode(params, frames)
        L = full_tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
        hidden, _ = task.model.decode_stack(
            params, full_tokens, positions, memory)
        ref_logits = task.model.logits(params, hidden)
    else:
        L = full_tokens.shape[1] + n_vis
        positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
        hidden, _, _ = task.model.forward(
            params, full_tokens, positions,
            patch_embeds=extra.get("patch_embeds"))
        ref_logits = task.model.logits(params, hidden[:, n_vis:])

    # ---- prefill on the first Lp tokens, then decode the rest one by one
    batch = {"tokens": full_tokens[:, :Lp], **extra}
    if cfg.encoder_decoder:
        batch["frames"] = frames
    caches, logits = jax.jit(task.prefill)(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(ref_logits[:, Lp - 1], np.float32),
        err_msg=f"{arch}: prefill last-logit mismatch", **_tol(arch))

    decode = jax.jit(task.decode_step)
    for t in range(Lgen):
        pos = Lp + t + (n_vis if not cfg.encoder_decoder else 0)
        step_batch = {
            "tokens": full_tokens[:, Lp + t : Lp + t + 1],
            "pos": jnp.asarray(pos, jnp.int32),
        }
        logits, caches = decode(params, step_batch, caches)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(ref_logits[:, Lp + t], np.float32),
            err_msg=f"{arch}: decode step {t} logits diverge", **_tol(arch))


def test_engine_generate_greedy_matches_manual():
    from repro.serve import engine as engine_lib

    cfg = configs.get_config("smollm-135m", reduced=True)
    task = registry.make_task(cfg)
    params = task.init(jax.random.PRNGKey(0))
    eng = engine_lib.Engine(task, params)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab_size),
        np.int32)
    out = eng.generate(prompts, engine_lib.GenerateConfig(max_new_tokens=4))
    assert out.shape == (2, 4)
    assert np.all(out >= 0) and np.all(out < cfg.vocab_size)
    # determinism
    out2 = eng.generate(prompts, engine_lib.GenerateConfig(max_new_tokens=4))
    np.testing.assert_array_equal(out, out2)
