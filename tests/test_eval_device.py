"""Parity, property, and golden-regression tests for the device-resident
evaluation engine (core/eval_device.py) against the frozen host reference
(core/eval.py).

The acceptance bar is *exact* agreement, not closeness: for every model x
task x filtered/raw setting the device engine must produce identical ranks
and identical metric floats, and the worker-sharded run (W=4) must equal
W=1.  The full model matrix is marked ``slow`` (run by the CI slow-suites
job); a transe smoke subset stays in tier-1.

``hypothesis`` is an optional test dep: when absent the property-based test
is skipped and a parametrized fixed-seed fallback covers the same check
path (same pattern as tests/test_kernels_rank_topk.py).
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import eval_device, kg_eval
from repro.core.models import KGConfig, get_model
from repro.data import kg as kg_lib

MODELS = ["transe", "transh", "distmult"]
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "eval_golden.json")


@pytest.fixture(scope="module")
def tiny_params(tiny_kg):
    cfg = KGConfig(
        n_entities=tiny_kg.n_entities, n_relations=tiny_kg.n_relations,
        dim=16)
    return {
        name: get_model(name).init_params(jax.random.PRNGKey(2), cfg)
        for name in MODELS
    }


def _assert_entity_parity(tiny_kg, params, model, **device_kw):
    host = kg_eval.entity_inference(
        params, tiny_kg.test, "l1", tiny_kg.known_set(), model=model,
        known_index=tiny_kg.known_index(), return_ranks=True)
    masks = tiny_kg.eval_filter_candidates()
    dev_ranks = eval_device.entity_ranks_device(
        params, tiny_kg.test, "l1", masks, model=model, **device_kw)
    dev = eval_device.entity_inference_device(
        params, tiny_kg.test, "l1", masks, model=model, **device_kw)
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(
                np.asarray(host[grp][side]),
                np.asarray(dev_ranks[grp][side]),
                err_msg=f"{model}/{grp}/{side}")
    assert host["raw"].row() == dev["raw"].row()
    assert host["filtered"].row() == dev["filtered"].row()


# ---------------------------------------------------------------------------
# Exact parity: the full model x task x filter matrix (slow job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("model", MODELS)
def test_entity_parity_exact(tiny_kg, tiny_params, model):
    _assert_entity_parity(tiny_kg, tiny_params[model], model, n_workers=2)


@pytest.mark.slow
@pytest.mark.parametrize("model", MODELS)
def test_relation_parity_exact(tiny_kg, tiny_params, model):
    host = kg_eval.relation_prediction(
        tiny_params[model], tiny_kg.test, "l1", model=model)
    dev, dev_ranks = eval_device.relation_prediction_device(
        tiny_params[model], tiny_kg.test, "l1", model=model, n_workers=2,
        return_ranks=True)
    # reference ranks rebuilt with the host engine's own scoring function
    scores = np.asarray(kg_eval._relation_scores(
        get_model(model), tiny_params[model], jnp.asarray(tiny_kg.test),
        "l1"))
    gold = scores[np.arange(len(tiny_kg.test)), tiny_kg.test[:, 1]]
    ref_ranks = 1 + (scores < gold[:, None]).sum(axis=1)
    np.testing.assert_array_equal(ref_ranks, np.asarray(dev_ranks))
    assert host.row() == dev.row()


@pytest.mark.slow
@pytest.mark.parametrize("model", MODELS)
def test_triplet_classification_parity_exact(tiny_kg, tiny_params, model):
    host = kg_eval.triplet_classification(
        tiny_params[model], tiny_kg.valid, tiny_kg.test,
        tiny_kg.n_entities, "l1", model=model)
    dev = eval_device.triplet_classification_device(
        tiny_params[model], tiny_kg.valid, tiny_kg.test,
        tiny_kg.n_entities, "l1", model=model)
    assert host == dev


@pytest.mark.slow
@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("filtered", [True, False])
def test_evaluate_all_parity_exact(tiny_kg, tiny_params, model, filtered):
    host = kg_eval.evaluate_all(
        tiny_params[model], tiny_kg, filtered=filtered, model=model)
    dev = kg_eval.evaluate_all(
        tiny_params[model], tiny_kg, filtered=filtered, model=model,
        engine="device", n_workers=2)
    assert host == dev


@pytest.mark.slow
@pytest.mark.parametrize("model", MODELS)
def test_w4_sharded_equals_w1(tiny_kg, tiny_params, model):
    masks = tiny_kg.eval_filter_candidates()
    r1 = eval_device.entity_ranks_device(
        tiny_params[model], tiny_kg.test, "l1", masks, model=model,
        n_workers=1)
    r4 = eval_device.entity_ranks_device(
        tiny_params[model], tiny_kg.test, "l1", masks, model=model,
        n_workers=4)
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(
                r1[grp][side], r4[grp][side],
                err_msg=f"{model}/{grp}/{side}")


# ---------------------------------------------------------------------------
# Tier-1 smoke subset (transe) — fast cross-section of the matrix above
# ---------------------------------------------------------------------------

def test_parity_smoke_transe(tiny_kg, tiny_params):
    _assert_entity_parity(tiny_kg, tiny_params["transe"], "transe",
                          n_workers=2, chunk=64)
    host = kg_eval.evaluate_all(tiny_params["transe"], tiny_kg,
                                model="transe")
    dev = kg_eval.evaluate_all(tiny_params["transe"], tiny_kg,
                               model="transe", engine="device", n_workers=4)
    assert host == dev


def test_chunk_size_invariance(tiny_kg, tiny_params):
    masks = tiny_kg.eval_filter_candidates()
    a = eval_device.entity_ranks_device(
        tiny_params["transe"], tiny_kg.test, "l1", masks, model="transe",
        chunk=32)
    b = eval_device.entity_ranks_device(
        tiny_params["transe"], tiny_kg.test, "l1", masks, model="transe",
        chunk=256)
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(a[grp][side], b[grp][side])


def test_shard_map_backend_matches_vmap(tiny_kg, tiny_params):
    # in-process single-device mesh, same pattern as the pipeline tests;
    # real multi-device shard_map semantics are covered by tests/helpers.
    # W=2 on the 1-device mesh exercises the multiple-worker-blocks-per-
    # shard path (each shard vmaps over W/M blocks — regression for the
    # bug where only block 0 of each shard was evaluated)
    mesh = jax.make_mesh((1,), ("workers",))
    masks = tiny_kg.eval_filter_candidates()
    v = eval_device.entity_ranks_device(
        tiny_params["transe"], tiny_kg.test, "l1", masks, model="transe",
        n_workers=2)
    s = eval_device.entity_ranks_device(
        tiny_params["transe"], tiny_kg.test, "l1", masks, model="transe",
        backend="shard_map", mesh=mesh, n_workers=2)
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(v[grp][side], s[grp][side])


def test_fused_relation_scan_matches_host_and_standalone(tiny_kg,
                                                         tiny_params):
    """Relation prediction fused into the entity scan body
    (entity_ranks_device(relations=True), what evaluate_all_device runs)
    must equal both the host reference and the standalone relation scan,
    rank for rank."""
    for model in MODELS:
        host_m, host_ranks = kg_eval.relation_prediction(
            tiny_params[model], tiny_kg.test, "l1", model=model,
            return_ranks=True)
        fused = eval_device.entity_ranks_device(
            tiny_params[model], tiny_kg.test, "l1",
            tiny_kg.eval_filter_candidates(), model=model, n_workers=2,
            relations=True)
        np.testing.assert_array_equal(
            host_ranks, fused["relation_ranks"], err_msg=model)
        standalone_m, standalone_ranks = (
            eval_device.relation_prediction_device(
                tiny_params[model], tiny_kg.test, "l1", model=model,
                n_workers=2, return_ranks=True))
        np.testing.assert_array_equal(
            host_ranks, standalone_ranks, err_msg=model)
        assert host_m.row() == standalone_m.row()


def test_tc_negatives_cached_and_identical(tiny_kg, tiny_params):
    """KG.tc_negatives caches the corruption draws (the in-loop eval calls
    the protocol every Reduce round) without changing a single draw."""
    a = tiny_kg.tc_negatives(0)
    b = tiny_kg.tc_negatives(0)
    assert a[0] is b[0] and a[1] is b[1]          # built once, cached
    direct = kg_eval._tc_negatives(
        tiny_kg.valid, tiny_kg.test, tiny_kg.n_entities, 0)
    np.testing.assert_array_equal(a[0], direct[0])
    np.testing.assert_array_equal(a[1], direct[1])
    # and the cached path yields the same accuracy as the self-built one
    tc_cached = eval_device.triplet_classification_device(
        tiny_params["transe"], tiny_kg.valid, tiny_kg.test,
        tiny_kg.n_entities, "l1", model="transe", negatives=a)
    tc_plain = eval_device.triplet_classification_device(
        tiny_params["transe"], tiny_kg.valid, tiny_kg.test,
        tiny_kg.n_entities, "l1", model="transe")
    assert tc_cached == tc_plain


def test_worker_map_validates_backend_and_mesh():
    """worker_map argument validation (the W % mesh-size divisibility check
    needs a multi-device mesh and is exercised by tests/helpers)."""
    from repro.parallel.util import worker_map

    with pytest.raises(ValueError, match="bad backend"):
        worker_map(lambda b, x: x, backend="pmap")
    with pytest.raises(ValueError, match="needs a mesh"):
        worker_map(lambda b, x: x, backend="shard_map")


def test_fused_true_requires_kernel(tiny_kg, tiny_params):
    """Explicit fused=True on a kernel-less model must raise, not silently
    fall back to the jnp path."""
    masks = tiny_kg.eval_filter_candidates()
    with pytest.raises(ValueError, match="no fused Pallas kernel"):
        eval_device.entity_ranks_device(
            tiny_params["distmult"], tiny_kg.test, "l1", masks,
            model="distmult", fused=True)


def test_fused_kernel_path_matches_reference(tiny_kg, tiny_params):
    """The rank_topk Pallas path (interpret mode off-TPU) against the exact
    jnp path — kernel-test tolerance: identical up to last-ulp tie flips."""
    masks = tiny_kg.eval_filter_candidates()
    test = tiny_kg.test[:48]
    tmasks = (masks[0][:48], masks[1][:48])
    exact = eval_device.entity_ranks_device(
        tiny_params["transe"], test, "l1", tmasks, model="transe",
        fused=False)
    fused = eval_device.entity_ranks_device(
        tiny_params["transe"], test, "l1", tmasks, model="transe",
        fused=True)
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            diff = np.abs(exact[grp][side].astype(np.int64)
                          - fused[grp][side].astype(np.int64))
            assert diff.max() <= 1, (grp, side, diff.max())


def test_fused_auto_resolution_off_tpu(tiny_params):
    """fused=None must resolve to the exact jnp path off TPU (parity by
    default on this container)."""
    from repro.kernels import ops

    model = get_model("transe")
    if jax.default_backend() == "tpu":
        assert ops.fused_eval_available(model)
    else:
        assert not ops.fused_eval_available(model)
    assert not ops.fused_eval_available(get_model("distmult"))


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis optional, fixed-seed fallback)
# ---------------------------------------------------------------------------

def _check_eval_invariants(seed):
    rng = np.random.default_rng(seed)
    E, R, k, Q, P = 40, 4, 8, 12, 3
    params = {
        "ent": jnp.asarray(rng.normal(size=(E, k)).astype(np.float32)),
        "rel": jnp.asarray(rng.normal(size=(R, k)).astype(np.float32)),
    }
    queries = np.stack([
        rng.integers(0, E, Q), rng.integers(0, R, Q), rng.integers(0, E, Q),
    ], axis=1).astype(np.int32)
    # random known-candidate masks; always include the gold id (as the
    # real masks do — test triplets are known) plus random others, pad = E
    tails = np.full((Q, P), E, np.int32)
    heads = np.full((Q, P), E, np.int32)
    for i in range(Q):
        tails[i, 0] = queries[i, 2]
        heads[i, 0] = queries[i, 0]
        tails[i, 1:] = rng.integers(0, E, P - 1)
        heads[i, 1:] = rng.integers(0, E, P - 1)

    ranks = eval_device.entity_ranks_device(
        params, queries, "l1", (tails, heads), model="transe",
        chunk=8, n_workers=2)
    for side in ("tail", "head"):
        raw = ranks["raw_ranks"][side]
        filt = ranks["filtered_ranks"][side]
        assert np.all(raw >= 1) and np.all(raw <= E), raw
        assert np.all(filt >= 1) and np.all(filt <= E), filt
        assert np.all(filt <= raw), (filt, raw)

    # permutation equivariance of ranks => invariance of every metric
    perm = rng.permutation(Q)
    ranks_p = eval_device.entity_ranks_device(
        params, queries[perm], "l1", (tails[perm], heads[perm]),
        model="transe", chunk=8, n_workers=2)
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(
                ranks_p[grp][side], ranks[grp][side][perm])


@pytest.mark.parametrize("seed", [0, 7, 123, 2**31 - 1])
def test_eval_invariants_fixed_seeds(seed):
    """Non-hypothesis fallback: always runs, fixed corpus of instances."""
    _check_eval_invariants(seed)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_eval_invariants(seed):
        _check_eval_invariants(seed)


def test_gold_tie_handling_deterministic():
    """Entities whose score exactly ties the gold never count against the
    rank (strict <), and repeated evaluation is bit-identical."""
    k = 4
    ent = np.zeros((5, k), np.float32)
    ent[0] = 0.0                     # head
    ent[1] = 1.0                     # gold tail: d = ||h + r - t|| = 0
    ent[2] = 1.0                     # exact tie with gold
    ent[3] = 0.5                     # strictly closer? d = 2.0 > 0 -> no
    ent[4] = 9.0                     # far
    rel = np.ones((1, k), np.float32)
    params = {"ent": jnp.asarray(ent), "rel": jnp.asarray(rel)}
    queries = np.array([[0, 0, 1]], np.int32)
    masks = (np.array([[1, 2]], np.int32), np.array([[0, 5]], np.int32))
    a = eval_device.entity_ranks_device(
        params, queries, "l1", masks, model="transe")
    b = eval_device.entity_ranks_device(
        params, queries, "l1", masks, model="transe")
    # gold distance 0; no entity is strictly closer; the tie (ent 2) and the
    # known candidate (also ent 2) are both excluded
    assert a["raw_ranks"]["tail"][0] == 1
    assert a["filtered_ranks"]["tail"][0] == 1
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(a[grp][side], b[grp][side])


# ---------------------------------------------------------------------------
# Data-layer filter structures
# ---------------------------------------------------------------------------

def test_filter_candidates_cached_and_exact(tiny_kg):
    a = tiny_kg.eval_filter_candidates()
    b = tiny_kg.eval_filter_candidates()
    assert a[0] is b[0] and a[1] is b[1]          # built once, cached
    by_hr, by_rt = tiny_kg.known_index()
    pad = tiny_kg.n_entities
    for i, (h, r, t) in enumerate(tiny_kg.test[:20].tolist()):
        row = [e for e in a[0][i].tolist() if e != pad]
        assert row == by_hr[(h, r)]
        row = [e for e in a[1][i].tolist() if e != pad]
        assert row == by_rt[(r, t)]


def test_filter_candidates_truncation_warns_once(tiny_kg):
    g = kg_lib.synthetic_kg(3, n_entities=150, n_relations=4,
                            n_triplets=1500)
    with pytest.warns(UserWarning, match="truncates the filtered-known"):
        t1, h1 = g.eval_filter_candidates(max_fanout=1)
    assert t1.shape[1] == 1 and h1.shape[1] == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # cached: no second warning
        g.eval_filter_candidates(max_fanout=1)


def test_truncated_masks_give_rank_upper_bounds(tiny_kg, tiny_params):
    exact = eval_device.entity_ranks_device(
        tiny_params["transe"], tiny_kg.test, "l1",
        tiny_kg.eval_filter_candidates(), model="transe")
    with pytest.warns(UserWarning):
        trunc_masks = tiny_kg.eval_filter_candidates(max_fanout=1)
    trunc = eval_device.entity_ranks_device(
        tiny_params["transe"], tiny_kg.test, "l1", trunc_masks,
        model="transe")
    for side in ("tail", "head"):
        assert np.all(trunc["filtered_ranks"][side]
                      >= exact["filtered_ranks"][side])


def test_host_engine_rejects_device_options(tiny_kg, tiny_params):
    with pytest.raises(ValueError, match="engine='device'"):
        kg_eval.evaluate_all(
            tiny_params["transe"], tiny_kg, model="transe", n_workers=4)
    with pytest.raises(ValueError, match="bad engine"):
        kg_eval.evaluate_all(
            tiny_params["transe"], tiny_kg, model="transe", engine="gpu")


# ---------------------------------------------------------------------------
# Golden-metrics regression: committed numbers for a fixed-seed graph
# ---------------------------------------------------------------------------

def _golden_setup(spec):
    graph = kg_lib.synthetic_kg(**spec["graph"])
    cfg = KGConfig(
        n_entities=graph.n_entities, n_relations=graph.n_relations,
        dim=spec["dim"])
    params = get_model(spec["model"]).init_params(
        jax.random.PRNGKey(spec["params_seed"]), cfg)
    return graph, params


@pytest.mark.parametrize("engine", ["host", "device"])
def test_golden_metrics(engine):
    """Eval refactors must not silently drift: both engines reproduce the
    committed evaluate_all numbers for a fixed-seed graph + fixed-seed
    params (regenerate with tests/golden/make_eval_golden.py after an
    *intentional* protocol change)."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for case in golden["cases"]:
        graph, params = _golden_setup(case)
        kw = {"n_workers": 2} if engine == "device" else {}
        got = kg_eval.evaluate_all(
            params, graph, model=case["model"], engine=engine, **kw)
        for task, row in case["metrics"].items():
            if isinstance(row, dict):
                for metric, want in row.items():
                    assert got[task][metric] == pytest.approx(
                        want, rel=1e-5, abs=1e-7), (
                        case["model"], task, metric)
            else:
                assert got[task] == pytest.approx(row, rel=1e-5), (
                    case["model"], task)
