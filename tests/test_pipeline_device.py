"""Tests for the device-resident training pipeline (scan-over-epochs engine,
core/mapreduce.py): block-size invariance, epoch scheduling (merge_every),
config validation, and the batching balance-rule diagnostics.

The acceptance bar: `block_epochs=1` and `block_epochs=E` must produce
bit-identical params and loss history for every registered model x paradigm
x backend — every per-epoch key is `fold_in`-derived from (seed, epoch), so
how epochs are grouped into compiled blocks cannot matter.

The full 12-cell invariance matrix is marked `slow` (run by the CI
slow-suites job alongside the device-eval parity matrix); the tier-1 run
keeps the merge_every invariance cell as its fast cross-section.
"""
import jax
import numpy as np
import pytest

from repro import kg as kg_api
from repro.core import mapreduce
from repro.data import kg as kg_lib

MODELS = ["transe", "transh", "distmult"]
EPOCHS = 4


def _one_device_mesh():
    return jax.make_mesh((1,), ("workers",))


def _fit_device(tiny_kg, *, epochs=EPOCHS, **kw):
    defaults = dict(
        pipeline="device", n_workers=2, dim=8, learning_rate=0.05,
        batch_size=64, seed=0)
    defaults.update(kw)
    return kg_api.fit(tiny_kg, epochs=epochs, **defaults)


def _assert_identical(r1, r2):
    np.testing.assert_array_equal(
        np.asarray(r1.loss_history, np.float32),
        np.asarray(r2.loss_history, np.float32))
    assert set(r1.params) == set(r2.params)
    for k in r1.params:
        np.testing.assert_array_equal(
            np.asarray(r1.params[k]), np.asarray(r2.params[k]),
            err_msg=f"table {k}")


# ---------------------------------------------------------------------------
# Block-size invariance (the acceptance matrix)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("paradigm", ["sgd", "bgd"])
@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_block_invariance(tiny_kg, model, paradigm, backend):
    kw = dict(model=model, paradigm=paradigm, backend=backend)
    if backend == "shard_map":
        # in-process single-device mesh; W>1 shard_map semantics are covered
        # by tests/helpers/multiworker_check.py (device-pipeline section)
        kw.update(mesh=_one_device_mesh(), n_workers=1)
    r1 = _fit_device(tiny_kg, block_epochs=1, **kw)
    rE = _fit_device(tiny_kg, block_epochs=EPOCHS, **kw)
    _assert_identical(r1, rE)


def test_block_invariance_with_merge_every(tiny_kg):
    """K local epochs between Reduces: grouping the rounds into blocks of
    one round vs all rounds in one block is still bit-identical."""
    kw = dict(model="transe", paradigm="sgd", backend="vmap",
              merge_every=2, epochs=6)
    r2 = _fit_device(tiny_kg, block_epochs=2, **kw)
    r6 = _fit_device(tiny_kg, block_epochs=6, **kw)
    _assert_identical(r2, r6)


# ---------------------------------------------------------------------------
# The schedule actually trains / actually changes the trajectory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paradigm", ["sgd", "bgd"])
def test_device_pipeline_learns(tiny_kg, paradigm):
    res = _fit_device(
        tiny_kg, model="transe", paradigm=paradigm, backend="vmap",
        n_workers=4, epochs=8, block_epochs=8, dim=16)
    assert res.loss_history[-1] < res.loss_history[0], res.loss_history


def test_merge_every_defers_reduces(tiny_kg):
    """K=2 runs a different (locally-drifting) trajectory than K=1, and
    still learns — the new scenario the scanned driver enables."""
    r1 = _fit_device(tiny_kg, model="transe", paradigm="sgd",
                     backend="vmap", epochs=6, block_epochs=6, merge_every=1)
    r2 = _fit_device(tiny_kg, model="transe", paradigm="sgd",
                     backend="vmap", epochs=6, block_epochs=6, merge_every=2)
    assert not np.array_equal(
        np.asarray(r1.params["ent"]), np.asarray(r2.params["ent"]))
    assert r2.loss_history[-1] < r2.loss_history[0], r2.loss_history


def test_callback_fires_at_block_boundaries(tiny_kg):
    calls = []
    _fit_device(tiny_kg, model="transe", paradigm="sgd", backend="vmap",
                epochs=6, block_epochs=2,
                callback=lambda e, l: calls.append((e, l)))
    assert [e for e, _ in calls] == [1, 3, 5]
    assert all(np.isfinite(l) for _, l in calls)


def test_device_history_matches_host_length_and_finite(tiny_kg):
    res = _fit_device(tiny_kg, model="distmult", paradigm="bgd",
                      backend="vmap", epochs=5, block_epochs=2)
    assert len(res.loss_history) == 5
    assert np.all(np.isfinite(res.loss_history))


# ---------------------------------------------------------------------------
# On-device re-partitioning (EpochSchedule.repartition_every)
# ---------------------------------------------------------------------------

def test_repartition_inf_is_identity(tiny_kg):
    """M >= epochs never leaves re-partition round 0 — which is defined as
    the original partition — so it must be bit-identical to M=None."""
    off = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=4,
                      block_epochs=4)
    inf = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=4,
                      block_epochs=4, repartition_every=10**6)
    _assert_identical(off, inf)


def test_repartition_changes_trajectory_and_learns(tiny_kg):
    off = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=6,
                      block_epochs=6)
    on = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=6,
                     block_epochs=6, repartition_every=2)
    assert not np.array_equal(
        np.asarray(off.params["ent"]), np.asarray(on.params["ent"]))
    assert on.loss_history[-1] < on.loss_history[0], on.loss_history


def test_repartition_block_invariance(tiny_kg):
    """The effective partition of epoch e is a pure function of (seed,
    e // M), so how epochs are grouped into blocks still cannot matter."""
    kw = dict(model="transe", backend="vmap", epochs=4, repartition_every=2)
    r1 = _fit_device(tiny_kg, block_epochs=1, **kw)
    r4 = _fit_device(tiny_kg, block_epochs=4, **kw)
    _assert_identical(r1, r4)


def test_repartition_requires_device_pipeline():
    with pytest.raises(ValueError, match="pipeline='device'"):
        mapreduce.MapReduceConfig(
            pipeline="host",
            schedule=mapreduce.EpochSchedule(repartition_every=2))


def test_repartition_every_validated():
    with pytest.raises(ValueError, match="repartition_every"):
        mapreduce.EpochSchedule(repartition_every=0)


# ---------------------------------------------------------------------------
# Params-buffer donation (MapReduceConfig.donate_params)
# ---------------------------------------------------------------------------

def test_donation_results_bit_identical(tiny_kg):
    on = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=4,
                     block_epochs=2, donate_params=True)
    off = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=4,
                      block_epochs=2, donate_params=False)
    _assert_identical(on, off)


def test_donation_preserves_caller_resume_params(tiny_kg):
    """The driver copies caller-provided params before the first donated
    block call, so the caller's buffers survive the run."""
    warm = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=2,
                       block_epochs=2)
    resumed = _fit_device(tiny_kg, model="transe", backend="vmap", epochs=2,
                          block_epochs=2, params=warm.params,
                          donate_params=True)
    # the original params must still be readable (not donated away)
    for k in warm.params:
        assert np.all(np.isfinite(np.asarray(warm.params[k])))
    assert resumed.epochs_run == 2


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def test_schedule_requires_device_pipeline():
    with pytest.raises(ValueError, match="pipeline='device'"):
        mapreduce.MapReduceConfig(
            pipeline="host", schedule=mapreduce.EpochSchedule(block_epochs=4))


def test_merge_every_requires_sgd():
    with pytest.raises(ValueError, match="SGD-paradigm"):
        mapreduce.MapReduceConfig(
            paradigm="bgd", pipeline="device",
            schedule=mapreduce.EpochSchedule(block_epochs=4, merge_every=2))


def test_block_must_be_multiple_of_merge_every():
    with pytest.raises(ValueError, match="multiple of"):
        mapreduce.EpochSchedule(block_epochs=3, merge_every=2)


def test_epochs_must_be_multiple_of_merge_every(tiny_kg):
    with pytest.raises(ValueError, match="multiple of"):
        _fit_device(tiny_kg, model="transe", paradigm="sgd", backend="vmap",
                    epochs=5, block_epochs=2, merge_every=2)


def test_bad_pipeline_name_rejected():
    with pytest.raises(ValueError, match="bad pipeline"):
        mapreduce.MapReduceConfig(pipeline="offload")


# ---------------------------------------------------------------------------
# Batching balance rule (strict/warn) + on-device batch determinism
# ---------------------------------------------------------------------------

def test_train_warns_once_on_remainder(tiny_kg, tiny_tcfg):
    cfg = mapreduce.MapReduceConfig(
        n_workers=2, backend="vmap", batch_size=64)   # 1125 % 64 != 0
    with pytest.warns(UserWarning, match="does not divide the per-worker"):
        mapreduce.train(tiny_kg, tiny_tcfg, cfg, epochs=1, seed=0)


def test_train_strict_batching_raises(tiny_kg, tiny_tcfg):
    cfg = mapreduce.MapReduceConfig(
        n_workers=2, backend="vmap", batch_size=64, strict_batching=True)
    with pytest.raises(ValueError, match="does not divide the per-worker"):
        mapreduce.train(tiny_kg, tiny_tcfg, cfg, epochs=1, seed=0)


def test_no_warning_when_batch_divides(tiny_kg, tiny_tcfg):
    import warnings as _w

    cfg = mapreduce.MapReduceConfig(
        n_workers=2, backend="vmap", batch_size=75)   # 1125 % 75 == 0
    with _w.catch_warnings():
        _w.simplefilter("error")
        mapreduce.train(tiny_kg, tiny_tcfg, cfg, epochs=1, seed=0)


def test_device_batches_deterministic_and_cover_split(tiny_kg):
    import jax.numpy as jnp

    part = jnp.asarray(kg_lib.partition_balanced(0, tiny_kg.train, 2))
    key = jax.random.PRNGKey(3)
    a = kg_lib.device_epoch_batches(key, part, 64)
    b = kg_lib.device_epoch_batches(key, part, 64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different key -> different permutation
    c = kg_lib.device_epoch_batches(jax.random.PRNGKey(4), part, 64)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    # shape/remainder rule matches the host path
    W, N_w, _ = part.shape
    assert a.shape == (W, N_w // 64, 64, 3)
    # every batch row comes from that worker's split
    for w in range(W):
        split = {tuple(t) for t in np.asarray(part[w]).tolist()}
        rows = np.asarray(a[w]).reshape(-1, 3)
        assert all(tuple(t) in split for t in rows[:64].tolist())
