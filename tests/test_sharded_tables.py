"""Sharded entity tables (``table_sharding="sharded"``): bit-identity
against the replicated layout across training, eval, and serving.

The acceptance bar (ISSUE 8): with the entity table split into contiguous
row blocks over the mesh axis — sparse deltas routed to their owning
shard in the Reduce, eval and serving scanning only shard-local candidate
blocks — every result is **bitwise** identical to the replicated layout:
final params for every merge strategy x paradigm x pipeline x backend,
block-size invariant and checkpoint-compatible across layouts; per-query
raw/filtered/relation ranks; and top-k answers including exclusion masks
and exact tie-breaks.  W=3 over 200 entities keeps the shard blocks
ragged (67/67/66 + one pad row), so every cell also exercises the
padded-tail masking.  The fast cross-sections run in tier-1; the full
model x strategy matrix is marked ``slow``; real W=8 shard_map cells live
in tests/helpers/multiworker_check.py (``check_sharded_tables``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kg as kg_api
from repro.core import eval_device, merge as merge_lib
from repro.core.models import get_model
from repro.data import kg as kg_lib
from repro.kb import KnowledgeBase
from repro.serve.kg_engine import KGQueryEngine

MODELS = ["transe", "transh", "distmult"]
STRATEGIES = list(merge_lib.STRATEGIES)
W = 3          # does not divide n_entities=200: ragged shard blocks


@pytest.fixture(scope="module")
def graph():
    return kg_lib.synthetic_kg(0, n_entities=200, n_relations=5,
                               n_triplets=1200)


@pytest.fixture(scope="module")
def masks(graph):
    """Filtered-ranking candidate masks for the first N test rows, aligned
    with the ``graph.test[:N]`` slices the eval cells query."""
    tails, heads = graph.eval_filter_candidates()

    def take(n):
        return tails[:n], heads[:n]

    return take


def _fit(graph, **kw):
    defaults = dict(model="transe", paradigm="sgd", backend="vmap",
                    n_workers=W, dim=8, learning_rate=0.05, batch_size=83,
                    seed=0, epochs=3, merge_transport="sparse")
    defaults.update(kw)
    return kg_api.fit(graph, **defaults)


def _assert_identical(r1, r2):
    np.testing.assert_array_equal(
        np.asarray(r1.loss_history, np.float32),
        np.asarray(r2.loss_history, np.float32))
    assert set(r1.params) == set(r2.params)
    for k in r1.params:
        np.testing.assert_array_equal(
            np.asarray(r1.params[k]), np.asarray(r2.params[k]),
            err_msg=f"table {k}")


def _pair(graph, **kw):
    rep = _fit(graph, table_sharding="replicated", **kw)
    sh = _fit(graph, table_sharding="sharded", **kw)
    return rep, sh


def _params(graph, model_name, seed=0, dim=8):
    model = get_model(model_name)
    kcfg, _ = kg_api.make_configs(graph, model=model_name, dim=dim)
    return model, model.init_params(jax.random.PRNGKey(seed), kcfg)


# ---------------------------------------------------------------------------
# Training: shard-routed Reduce == replicated Reduce, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_matches_replicated_host(graph, strategy):
    """Every merge strategy, host pipeline: the per-shard candidate union
    + local merge reassembles exactly the replicated merge's output."""
    _assert_identical(*_pair(graph, strategy=strategy))


def test_sharded_matches_replicated_device(graph):
    """Device pipeline with deferred Reduces: K local epochs of drift
    between shard-routed merges."""
    _assert_identical(*_pair(
        graph, pipeline="device", epochs=4, block_epochs=2, merge_every=2,
        strategy="average_all"))


@pytest.mark.parametrize("pipeline", ["host", "device"])
def test_sharded_matches_replicated_bgd(graph, pipeline):
    kw = dict(paradigm="bgd", pipeline=pipeline)
    if pipeline == "device":
        kw.update(epochs=4, block_epochs=2)
    _assert_identical(*_pair(graph, **kw))


def test_sharded_matches_replicated_shard_map(graph):
    """In-process single-device mesh; real W=8 shard_map bit-identity is
    covered by tests/helpers/multiworker_check.py."""
    mesh = jax.make_mesh((1,), ("workers",))
    _assert_identical(*_pair(
        graph, backend="shard_map", mesh=mesh, n_workers=1, batch_size=187,
        pipeline="device", epochs=4, block_epochs=2))


def test_sharded_block_size_invariant(graph):
    kw = dict(pipeline="device", table_sharding="sharded", epochs=4,
              merge_every=2)
    _assert_identical(_fit(graph, block_epochs=2, **kw),
                      _fit(graph, block_epochs=4, **kw))


def test_sharded_requires_sparse_transport(graph):
    with pytest.raises(ValueError, match="merge_transport='sparse'"):
        _fit(graph, merge_transport="dense", table_sharding="sharded")


def test_checkpoint_moves_between_layouts(graph, tmp_path):
    """``table_sharding`` is deliberately absent from the resume manifest:
    a replicated-trained checkpoint resumes under the sharded layout (and
    vice versa) and still reproduces the uninterrupted run exactly."""
    kw = dict(pipeline="device", block_epochs=2, checkpoint_every=2)
    ref = _fit(graph, epochs=4, ckpt_dir=str(tmp_path / "ref"), **kw)
    for first, second in (("replicated", "sharded"),
                          ("sharded", "replicated")):
        d = str(tmp_path / f"{first}-to-{second}")
        _fit(graph, epochs=2, table_sharding=first, ckpt_dir=d, **kw)
        res = _fit(graph, epochs=4, table_sharding=second, ckpt_dir=d,
                   resume=True, **kw)
        for k in ref.params:
            np.testing.assert_array_equal(
                np.asarray(ref.params[k]), np.asarray(res.params[k]),
                err_msg=f"{first}->{second} table {k}")


@pytest.mark.slow
@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_matrix(graph, model, strategy):
    _assert_identical(*_pair(
        graph, model=model, strategy=strategy, pipeline="device", epochs=4,
        block_epochs=2, merge_every=2))


# ---------------------------------------------------------------------------
# The per-model slice contract the sharded scan is built on
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model_name", MODELS)
@pytest.mark.parametrize("norm", ["l1", "l2"])
def test_candidate_slice_energies_contract(graph, model_name, norm):
    """``candidate_slice_energies`` == columns [lo, lo+n) of the full
    score matrix, **bitwise**, for ragged block offsets — the per-model
    contract every shard-local scan rests on (models that slice the
    entity table before scoring must reduce in the same order the full
    matrix does)."""
    model, params = _params(graph, model_name, seed=2)
    rng = np.random.default_rng(0)
    q = jnp.asarray(np.stack([
        rng.integers(0, 200, 16), rng.integers(0, 5, 16),
        rng.integers(0, 200, 16)], axis=1).astype(np.int32))
    for side in ("tail", "head"):
        full = np.asarray(model.candidate_energies(params, q, side, norm))
        for lo, n in ((0, 200), (67, 67), (134, 66), (13, 5)):
            sl = model.candidate_slice_energies(
                params, q, side, norm, lo=jnp.int32(lo), n=n)
            np.testing.assert_array_equal(
                full[:, lo:lo + n], np.asarray(sl),
                err_msg=f"{model_name}/{side}/{norm} lo={lo} n={n}")


# ---------------------------------------------------------------------------
# Eval: shard-local candidate scan + exact cross-shard combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model_name", MODELS)
def test_sharded_eval_ranks_bitwise(graph, masks, model_name):
    """Raw, filtered, and relation ranks from the sharded scan equal the
    replicated scan's exactly — gold via cross-shard min, counts via
    integer sums, pad rows masked by id."""
    model, params = _params(graph, model_name, seed=1)
    test = graph.test[:48]
    kw = dict(model=model, cand_masks=masks(48), n_workers=W,
              relations=True)
    rep = eval_device.entity_ranks_device(params, test, **kw)
    sh = eval_device.entity_ranks_device(
        params, test, table_sharding="sharded", **kw)
    for group in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(
                rep[group][side], sh[group][side],
                err_msg=f"{model_name} {group}/{side}")
    np.testing.assert_array_equal(rep["relation_ranks"],
                                  sh["relation_ranks"])


def test_sharded_eval_chunk_invariant(graph, masks):
    """The chunked scan layout cannot matter: different chunk sizes give
    identical sharded ranks (queries pad, never split, across shards)."""
    model, params = _params(graph, "transe", seed=1)
    test = graph.test[:32]
    outs = [eval_device.entity_ranks_device(
        params, test, model=model, cand_masks=masks(32), n_workers=W,
        table_sharding="sharded", chunk=c) for c in (8, 64)]
    for side in ("tail", "head"):
        np.testing.assert_array_equal(outs[0]["raw_ranks"][side],
                                      outs[1]["raw_ranks"][side])


def test_sharded_eval_shard_map_single_device(graph, masks):
    model, params = _params(graph, "transe", seed=1)
    test = graph.test[:24]
    rep = eval_device.entity_ranks_device(
        params, test, model=model, cand_masks=masks(24), n_workers=1)
    sh = eval_device.entity_ranks_device(
        params, test, model=model, cand_masks=masks(24), n_workers=1,
        backend="shard_map", mesh=jax.make_mesh((1,), ("workers",)),
        table_sharding="sharded")
    for side in ("tail", "head"):
        np.testing.assert_array_equal(rep["raw_ranks"][side],
                                      sh["raw_ranks"][side])


def test_sharded_eval_rejects_fused_and_bad_value(graph):
    model, params = _params(graph, "transe")
    with pytest.raises(ValueError, match="fused"):
        eval_device.entity_ranks_device(
            params, graph.test[:4], model=model, n_workers=W, fused=True,
            table_sharding="sharded")
    with pytest.raises(ValueError, match="table_sharding"):
        eval_device.entity_ranks_device(
            params, graph.test[:4], model=model, table_sharding="diagonal")


# ---------------------------------------------------------------------------
# Serving: shard-local top-k + cross-shard combine, ties exact
# ---------------------------------------------------------------------------

def _engines(graph, model_name, **kw):
    model, params = _params(graph, model_name, seed=3)
    rep = KGQueryEngine(model, params, n_workers=W, **kw)
    sh = KGQueryEngine(model, params, n_workers=W,
                       table_sharding="sharded", **kw)
    return rep, sh


def _assert_query_equal(a, b, label=""):
    np.testing.assert_array_equal(a.ids, b.ids, err_msg=label)
    np.testing.assert_array_equal(a.energies, b.energies, err_msg=label)


@pytest.mark.parametrize("model_name", MODELS)
def test_sharded_topk_bitwise(graph, model_name):
    """k < R, k > R (local kk cut), and k = E (full table) — every local
    cut provably keeps each global winner, so the combined top-k matches
    the replicated one bitwise, ids and energies."""
    rep, sh = _engines(graph, model_name)
    rows = graph.test[:12]
    h, r, t = rows[:, 0], rows[:, 1], rows[:, 2]
    for k in (5, 80, 200):
        _assert_query_equal(rep.query_tails(h, r, k=k),
                            sh.query_tails(h, r, k=k),
                            f"{model_name} tails k={k}")
        _assert_query_equal(rep.query_heads(t, r, k=k),
                            sh.query_heads(t, r, k=k),
                            f"{model_name} heads k={k}")
    _assert_query_equal(rep.query_relations(h, t, k=3),
                        sh.query_relations(h, t, k=3))


def test_sharded_topk_with_exclusion(graph):
    """Exclusion ids scatter into their owning shard's slice only; the
    padded exclusion sentinel (id = E) lands in no shard."""
    rep, sh = _engines(graph, "transe")
    rows = graph.test[:6]
    h, r = rows[:, 0], rows[:, 1]
    base = rep.query_tails(h, r, k=8)
    ex = np.sort(base.ids[:, :3].astype(np.int32), axis=1)
    ex = np.concatenate(     # ragged width + explicit pad sentinels
        [ex, np.full((len(ex), 2), 200, np.int32)], axis=1)
    a = rep.query_tails(h, r, k=8, exclude=ex)
    b = sh.query_tails(h, r, k=8, exclude=ex)
    _assert_query_equal(a, b, "excluded tails")
    for i in range(len(ex)):
        assert not set(ex[i, :3].tolist()) & set(
            int(x) for x in b.ids[i][np.isfinite(b.energies[i])])


def test_sharded_topk_tie_break_exact(graph):
    """All-zero tables tie every candidate; lax.top_k breaks ties toward
    the lowest index, and the shard-major combine preserves exactly that
    global order — so even fully degenerate scores pick identical ids."""
    model = get_model("transe")
    params = {"ent": jnp.zeros((200, 8)), "rel": jnp.zeros((5, 8))}
    rep = KGQueryEngine(model, params, n_workers=W)
    sh = KGQueryEngine(model, params, n_workers=W, table_sharding="sharded")
    q = np.zeros(4, np.int32)
    for k in (5, 67, 80):
        _assert_query_equal(rep.query_tails(q, q, k=k),
                            sh.query_tails(q, q, k=k), f"ties k={k}")


def test_sharded_rank_and_score_parity(graph, masks):
    """The engine's rank() threads table_sharding into the eval scan;
    score() never shards (full-row lookups)."""
    rep, sh = _engines(graph, "transe")
    rows = graph.test[:16]
    np.testing.assert_array_equal(rep.rank(rows, "tail"),
                                  sh.rank(rows, "tail"))
    np.testing.assert_array_equal(
        rep.score(rows[:, 0], rows[:, 1], rows[:, 2]),
        sh.score(rows[:, 0], rows[:, 1], rows[:, 2]))


def test_engine_rejects_bad_sharding_config(graph):
    model, params = _params(graph, "transe")
    with pytest.raises(ValueError, match="table_sharding"):
        KGQueryEngine(model, params, n_workers=W, table_sharding="nope")
    with pytest.raises(ValueError, match="mesh"):
        KGQueryEngine(model, params, n_workers=2, backend="shard_map",
                      table_sharding="sharded")


# ---------------------------------------------------------------------------
# End-to-end threading: kg.fit knob, KnowledgeBase engines, evaluate
# ---------------------------------------------------------------------------

def test_kb_engine_cache_keys_on_sharding(graph):
    model, params = _params(graph, "transe")
    kb = KnowledgeBase(model, params, graph=graph)
    sh = kb.engine(n_workers=W, table_sharding="sharded")
    assert kb.engine(n_workers=W, table_sharding="sharded") is sh
    assert kb.engine(n_workers=W) is not sh
    assert sh.table_sharding == "sharded"


def test_kb_evaluate_sharded_parity(graph):
    """The full three-task protocol through the public artifact API:
    metrics from the sharded device engine equal the replicated ones."""
    model, params = _params(graph, "transe", seed=4)
    kb = KnowledgeBase(model, params, graph=graph)
    rep = kb.evaluate(engine="device", n_workers=W)
    sh = kb.evaluate(engine="device", n_workers=W,
                     table_sharding="sharded")
    assert rep == sh


def test_fit_threads_sharding_into_result(graph):
    """kg.fit(table_sharding=...) flows into MapReduceConfig — the pair
    helper above depends on it, pin it explicitly once."""
    _, mcfg = kg_api.make_configs(graph, merge_transport="sparse",
                                  table_sharding="sharded")
    assert mcfg.table_sharding == "sharded"
    with pytest.raises(ValueError, match="merge_transport"):
        kg_api.make_configs(graph, table_sharding="sharded")
