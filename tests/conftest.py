"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the 1 real CPU device; multi-device semantics are
tested via subprocesses (tests/helpers/)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tiny_kg():
    from repro.data import kg as kg_lib

    return kg_lib.synthetic_kg(0, n_entities=300, n_relations=6, n_triplets=2500)


@pytest.fixture(scope="session")
def tiny_tcfg(tiny_kg):
    from repro.core import transe

    return transe.TransEConfig(
        n_entities=tiny_kg.n_entities,
        n_relations=tiny_kg.n_relations,
        dim=16,
        margin=1.0,
        norm="l1",
        learning_rate=0.05,
    )
