"""Unit tests for the TransE model layer (paper §2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import negative, transe


def make_cfg(**kw):
    base = dict(n_entities=50, n_relations=5, dim=8, margin=1.0, norm="l1",
                learning_rate=0.1)
    base.update(kw)
    return transe.TransEConfig(**base)


class TestInit:
    def test_shapes_and_bounds(self):
        cfg = make_cfg()
        p = transe.init_params(jax.random.PRNGKey(0), cfg)
        assert p["ent"].shape == (50, 8)
        assert p["rel"].shape == (5, 8)
        bound = 6.0 / np.sqrt(8)
        assert np.all(np.abs(p["ent"]) <= bound)

    def test_relations_normalized_at_init(self):
        cfg = make_cfg()
        p = transe.init_params(jax.random.PRNGKey(0), cfg)
        norms = np.linalg.norm(p["rel"], axis=1)
        np.testing.assert_allclose(norms, 1.0, rtol=1e-5)

    def test_bad_norm_rejected(self):
        with pytest.raises(ValueError):
            make_cfg(norm="l3")


class TestEnergy:
    def test_perfect_translation_has_zero_energy(self):
        p = {
            "ent": jnp.array([[0.0, 0.0], [1.0, 2.0]]),
            "rel": jnp.array([[1.0, 2.0]]),
        }
        trip = jnp.array([[0, 0, 1]])
        for norm in ("l1", "l2"):
            d = transe.energy(p, trip, norm)
            assert float(d[0]) < 1e-5

    def test_l1_vs_l2(self):
        p = {
            "ent": jnp.array([[0.0, 0.0], [1.0, 1.0]]),
            "rel": jnp.array([[0.0, 0.0]]),
        }
        trip = jnp.array([[0, 0, 1]])
        assert float(transe.energy(p, trip, "l1")[0]) == pytest.approx(2.0)
        assert float(transe.energy(p, trip, "l2")[0]) == pytest.approx(
            np.sqrt(2.0), rel=1e-4
        )

    def test_batch_shape(self):
        cfg = make_cfg()
        p = transe.init_params(jax.random.PRNGKey(0), cfg)
        trip = jnp.zeros((7, 3), jnp.int32)
        assert transe.energy(p, trip, "l1").shape == (7,)


class TestLoss:
    def test_hinge_zero_when_margin_satisfied(self):
        d_pos = jnp.array([0.0])
        d_neg = jnp.array([5.0])
        assert float(transe.pairwise_hinge(d_pos, d_neg, 1.0)[0]) == 0.0

    def test_hinge_positive_when_violated(self):
        assert float(
            transe.pairwise_hinge(jnp.array([2.0]), jnp.array([1.0]), 1.0)[0]
        ) == pytest.approx(2.0)

    def test_gradient_zero_for_satisfied_pairs(self):
        """If every pair satisfies the margin, the loss is flat -> zero grad."""
        p = {
            "ent": jnp.array([[0.0, 0.0], [1.0, 0.0], [10.0, 10.0]]),
            "rel": jnp.array([[1.0, 0.0]]),
        }
        pos = jnp.array([[0, 0, 1]])   # d = 0
        neg = jnp.array([[0, 0, 2]])   # d large
        g = jax.grad(transe.margin_loss)(p, pos, neg, margin=1.0, norm="l1")
        assert float(jnp.abs(g["ent"]).max()) == 0.0


class TestTraining:
    def test_sgd_step_reduces_violation(self):
        cfg = make_cfg(norm="l2", learning_rate=0.05, normalize="none")
        p = transe.init_params(jax.random.PRNGKey(1), cfg)
        pos = jnp.array([[0, 0, 1], [2, 1, 3]], jnp.int32)
        neg = jnp.array([[0, 0, 7], [9, 1, 3]], jnp.int32)
        l0 = transe.margin_loss(p, pos, neg, margin=cfg.margin, norm=cfg.norm)
        for _ in range(60):
            p, _ = transe.sgd_step(p, pos, neg, cfg)
        l1 = transe.margin_loss(p, pos, neg, margin=cfg.margin, norm=cfg.norm)
        assert float(l1) < float(l0)

    def test_normalize_entities_unit_norm(self):
        cfg = make_cfg()
        p = transe.init_params(jax.random.PRNGKey(0), cfg)
        p = transe.normalize_entities(p)
        np.testing.assert_allclose(
            np.linalg.norm(p["ent"], axis=1), 1.0, rtol=1e-5
        )

    def test_run_epoch_stats_counts(self):
        """Touch counts must equal the number of pos+neg occurrences."""
        cfg = make_cfg(normalize="none")
        p = transe.init_params(jax.random.PRNGKey(0), cfg)
        pos = jnp.array([[[0, 0, 1], [2, 1, 3]]], jnp.int32)  # (S=1, B=2, 3)
        neg = jnp.array([[[4, 0, 1], [2, 1, 5]]], jnp.int32)
        _, stats = transe.run_epoch(p, pos, neg, cfg)
        cnt = np.asarray(stats.ent_count)
        # pos heads 0,2; pos tails 1,3; neg heads 4,2; neg tails 1,5
        assert cnt[0] == 1 and cnt[2] == 2 and cnt[1] == 2
        assert cnt[3] == 1 and cnt[4] == 1 and cnt[5] == 1
        assert np.asarray(stats.rel_count)[0] == 1
        assert np.asarray(stats.rel_count)[1] == 1

    def test_bgd_matches_manual_gradient(self):
        cfg = make_cfg(normalize="none")
        p = transe.init_params(jax.random.PRNGKey(0), cfg)
        pos = jnp.array([[0, 0, 1]], jnp.int32)
        neg = jnp.array([[0, 0, 2]], jnp.int32)
        loss, grads = transe.batch_gradients(p, pos, neg, cfg)
        p2 = transe.apply_gradients(p, grads, cfg.learning_rate)
        manual = jax.tree.map(
            lambda a, g: a - cfg.learning_rate * g, p, grads
        )
        np.testing.assert_allclose(p2["ent"], manual["ent"])


class TestNegativeSampling:
    def test_corruption_changes_exactly_one_side(self):
        trip = jnp.tile(jnp.array([[3, 1, 7]], jnp.int32), (256, 1))
        neg = negative.corrupt_unif(jax.random.PRNGKey(0), trip, 50)
        neg = np.asarray(neg)
        head_changed = neg[:, 0] != 3
        tail_changed = neg[:, 2] != 7
        assert np.all(head_changed ^ tail_changed)     # exactly one side
        assert np.all(neg[:, 1] == 1)                  # relation untouched

    def test_replacement_never_equals_original(self):
        trip = jnp.tile(jnp.array([[3, 1, 7]], jnp.int32), (512, 1))
        neg = np.asarray(negative.corrupt_unif(jax.random.PRNGKey(1), trip, 50))
        assert not np.any((neg[:, 0] == 3) & (neg[:, 2] == 7))

    def test_bern_stats(self):
        trips = np.array([[0, 0, 1], [0, 0, 2], [0, 0, 3], [5, 1, 6]], np.int32)
        probs = negative.bernoulli_stats(trips, 2)
        # relation 0: 1 head, 3 tails -> tph=3, hpt=1 -> P(corrupt head)=0.75
        assert probs[0] == pytest.approx(0.75)
        assert probs[1] == pytest.approx(0.5)

    def test_make_negatives_stacked_shapes(self):
        pos = jnp.zeros((4, 3, 8, 3), jnp.int32)
        neg = negative.make_negatives(jax.random.PRNGKey(0), pos, 50)
        assert neg.shape == pos.shape
