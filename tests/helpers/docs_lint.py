"""Docs drift guard (CI lint job): the docs tree must track the code.

    PYTHONPATH=src python tests/helpers/docs_lint.py

Checks, each a hard failure:

  1. README.md and docs/architecture.md + docs/benchmarks.md exist.
  2. Every committed ``BENCH_*.json`` at the repo root is named in
     ``docs/benchmarks.md`` (a new bench without a docs section — or a
     renamed artifact orphaning its section — fails here, not in review).
  3. Every fenced ``python`` block in README.md parses, and every
     ``import`` / ``from ... import`` line in those blocks actually
     resolves — the quickstart cannot silently rot when the API moves.
  4. Relative markdown links in README.md and docs/*.md point at files
     that exist.

Pure stdlib + the repo's own imports; no pytest dependency so the CI
lint job can run it before the test extras install.
"""
from __future__ import annotations

import ast
import glob
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))

FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
# [text](target) — skip http(s), mailto, and pure #anchors
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def _fail(problems: list, msg: str) -> None:
    problems.append(msg)


def check_tree(problems: list) -> None:
    for rel in ("README.md", "docs/architecture.md", "docs/benchmarks.md"):
        if not os.path.exists(os.path.join(ROOT, rel)):
            _fail(problems, f"missing {rel}")


def check_bench_docs(problems: list) -> None:
    docs_path = os.path.join(ROOT, "docs", "benchmarks.md")
    if not os.path.exists(docs_path):
        return  # already reported by check_tree
    with open(docs_path) as f:
        text = f.read()
    for path in sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json"))):
        name = os.path.basename(path)
        if name not in text:
            _fail(problems,
                  f"{name} committed at the repo root but never named in "
                  "docs/benchmarks.md — add its section")


def check_readme_snippets(problems: list) -> None:
    readme = os.path.join(ROOT, "README.md")
    if not os.path.exists(readme):
        return
    with open(readme) as f:
        blocks = FENCE_RE.findall(f.read())
    if not blocks:
        _fail(problems, "README.md has no ```python quickstart block")
    for i, block in enumerate(blocks):
        try:
            tree = ast.parse(block)
        except SyntaxError as e:
            _fail(problems, f"README.md python block {i}: syntax error: {e}")
            continue
        # execute only the import statements: the snippet's names must
        # exist even though running the full training loop is out of scope
        imports = [node for node in tree.body
                   if isinstance(node, (ast.Import, ast.ImportFrom))]
        for node in imports:
            src = ast.get_source_segment(block, node) or ""
            try:
                exec(compile(ast.Module([node], []), "<readme>", "exec"), {})
            except Exception as e:
                _fail(problems,
                      f"README.md python block {i}: {src!r} failed: "
                      f"{type(e).__name__}: {e}")


def check_links(problems: list) -> None:
    pages = [os.path.join(ROOT, "README.md")]
    pages += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    for page in pages:
        if not os.path.exists(page):
            continue
        base = os.path.dirname(page)
        with open(page) as f:
            targets = LINK_RE.findall(f.read())
        for target in targets:
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not os.path.exists(os.path.normpath(os.path.join(base,
                                                                target))):
                rel = os.path.relpath(page, ROOT)
                _fail(problems, f"{rel}: broken link -> {target}")


def main() -> int:
    problems: list = []
    check_tree(problems)
    check_bench_docs(problems)
    check_readme_snippets(problems)
    check_links(problems)
    if problems:
        print("docs-lint: FAIL", flush=True)
        for p in problems:
            print(f"  {p}", flush=True)
        return 1
    print("docs-lint: OK (tree, bench sections, README snippets, links)",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
