"""Subprocess helper: the shard_map MoE dispatch must match the scatter
dispatch numerically (same routing, same experts, same combine) on a real
multi-device mesh."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import moe as moe_lib

cfg = configs.get_config("qwen2-moe-a2.7b", reduced=True).reduced(
    n_experts=8, top_k=2, moe_d_ff=16, d_model=32, capacity_factor=4.0,
    sharding_profile="fsdp_tp",
)

key = jax.random.PRNGKey(0)
p = moe_lib.init_moe(key, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      dtype=jnp.float32)

# reference: scatter path (no mesh)
ref_out, ref_aux = jax.jit(
    lambda p, x: moe_lib._apply_moe_scatter(p, x, cfg))(p, x)

mesh = jax.make_mesh((4, 2), ("data", "model"))
with mesh:
    got_out, got_aux = jax.jit(
        lambda p, x: moe_lib.apply_moe(p, x, cfg))(p, x)

np.testing.assert_allclose(
    np.asarray(got_out, np.float32), np.asarray(ref_out, np.float32),
    rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(float(got_aux), float(ref_aux), rtol=1e-4)

# gradients must match too (dispatch is differentiated in training)
def loss_scatter(p, x):
    o, a = moe_lib._apply_moe_scatter(p, x, cfg)
    return jnp.sum(o ** 2) + a

def loss_sharded(p, x):
    o, a = moe_lib.apply_moe(p, x, cfg)
    return jnp.sum(o ** 2) + a

g_ref = jax.jit(jax.grad(loss_scatter))(p, x)
with mesh:
    g_got = jax.jit(jax.grad(loss_sharded))(p, x)
for (ka, a), (kb, b) in zip(
        sorted(jax.tree_util.tree_flatten_with_path(g_ref)[0],
               key=lambda t: str(t[0])),
        sorted(jax.tree_util.tree_flatten_with_path(g_got)[0],
               key=lambda t: str(t[0]))):
    np.testing.assert_allclose(
        np.asarray(b, np.float32), np.asarray(a, np.float32),
        rtol=5e-4, atol=5e-5, err_msg=str(ka))

print("MOE SHARDMAP CHECK PASSED")
