"""Subprocess helper: real multi-device semantics checks.

Run with 8 forced host devices (the parent test sets XLA_FLAGS).  Asserts:
  1. shard_map SGD epoch (psum Reduce)      == vmap SGD epoch (stacked Reduce)
  2. shard_map SGD epoch (allgather Reduce) == vmap SGD epoch
  3. shard_map BGD epoch                    == vmap BGD epoch
  4. cross-pod local_sgd outer_merge: average/compressed/liveness semantics
  5. device pipeline (scan-over-epochs blocks): shard_map == vmap for both
     paradigms, incl. merge_every > 1 — the two backends derive identical
     per-worker fold_in keys, so batches/negatives match exactly
  6. device eval engine: shard_map query sharding == vmap (exact ranks) at
     W == mesh size AND W == 2x mesh size (multiple worker blocks per
     shard), and a W that does not divide over the mesh axis raises
  7. on-device re-partitioning (repartition_every): shard_map == vmap —
     the shard path all-gathers and slices the same global permutation the
     vmap path applies directly
  8. in-loop eval trace (kg.fit(eval_every=...)): a shard_map training run
     produces the same trace structure and (to collective-reordering
     tolerance) the same metric curve as the vmap run
  9. checkpoint/resume + serving: a resumed shard_map device-pipeline run
     is bit-identical to its own unbroken run, and the KnowledgeBase
     query engine's shard_map top-k equals the vmap engine exactly
     (ids and energies), raw and filtered
 10. sparse Reduce transport (merge_transport="sparse") at real W=8:
     shard_map sparse == vmap sparse == vmap dense bit-identically, for
     both the every-epoch and merge_every=2 schedules
 11. sharded entity tables (table_sharding="sharded") at real W=8: the
     shard-routed Reduce, the shard-local eval scan, and the shard-local
     serving top-k are each bit-identical to the replicated layout on a
     real 8-device mesh (training params, raw/filtered ranks, and top-k
     ids + energies including exclusion)
 12. bounded-staleness Reduce (staleness=2) at real W=8: shard_map ==
     vmap params bit-for-bit under dense, sparse, and sparse+sharded
     configurations (the stale all-gather replay on a real mesh)
Exit code 0 on success.
"""
import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import local_sgd, mapreduce, negative, transe
from repro.data import kg as kg_lib
from repro.parallel.util import shard_map

W = 8
assert len(jax.devices()) == W, f"expected {W} devices, got {len(jax.devices())}"


def check_engine():
    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    tcfg = transe.TransEConfig(
        n_entities=kg.n_entities, n_relations=kg.n_relations, dim=8,
        learning_rate=0.05,
    )
    mesh = jax.make_mesh((W,), ("workers",))
    part = kg_lib.partition_balanced(0, kg.train, W)
    pos = jnp.asarray(kg_lib.epoch_batches(0, 0, part, 16))
    neg = negative.make_negatives(jax.random.PRNGKey(1), pos, tcfg.n_entities)
    params = transe.init_params(jax.random.PRNGKey(2), tcfg)
    mk = jax.random.PRNGKey(3)

    for strategy in ("average", "miniloss_perkey", "miniloss_global", "random"):
        cfg_v = mapreduce.MapReduceConfig(
            n_workers=W, strategy=strategy, backend="vmap", batch_size=16)
        ref, ref_loss = mapreduce.sgd_epoch_vmap(params, pos, neg, cfg_v, tcfg, mk)
        for impl in ("psum", "allgather"):
            cfg_s = mapreduce.MapReduceConfig(
                n_workers=W, strategy=strategy, reduce_impl=impl,
                backend="shard_map", batch_size=16)
            with mesh:
                got, got_loss = mapreduce.sgd_epoch_shard(
                    params, pos, neg, cfg_s, tcfg, mk, mesh)
            for k in ("ent", "rel"):
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(ref[k]), rtol=1e-4, atol=1e-5,
                    err_msg=f"SGD {strategy}/{impl} table {k}",
                )
            np.testing.assert_allclose(
                float(got_loss), float(ref_loss), rtol=1e-4,
                err_msg=f"{strategy}/{impl} loss")
        print(f"sgd {strategy}: shard_map(psum & allgather) == vmap  OK")

    cfg_v = mapreduce.MapReduceConfig(
        n_workers=W, paradigm="bgd", backend="vmap", batch_size=16)
    ref, _ = mapreduce.bgd_epoch_vmap(params, pos, neg, cfg_v, tcfg)
    cfg_s = mapreduce.MapReduceConfig(
        n_workers=W, paradigm="bgd", backend="shard_map", batch_size=16)
    got, _ = mapreduce.bgd_epoch_shard(params, pos, neg, cfg_s, tcfg, mesh)
    np.testing.assert_allclose(
        np.asarray(got["ent"]), np.asarray(ref["ent"]), rtol=1e-4, atol=1e-5)
    print("bgd: shard_map == vmap  OK")


def check_outer_merge():
    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    rng = np.random.default_rng(0)
    per_pod = jnp.asarray(rng.normal(size=(4, 6, 3)).astype(np.float32))
    anchor = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))
    losses = jnp.asarray(np.array([0.5, 0.2, 0.9, 0.4], np.float32))
    live = jnp.asarray(np.array([1.0, 1.0, 0.0, 1.0], np.float32))

    def run(strategy, compress, use_liveness):
        cfg = local_sgd.OuterConfig(strategy=strategy, compress=compress)

        def f(p, loss, lv):
            st = local_sgd.OuterState(anchor=anchor, momentum=None)
            merged, _ = local_sgd.outer_merge(
                p[0], st, cfg, local_loss=loss[0],
                key=jax.random.PRNGKey(0),
                liveness=lv[0] if use_liveness else None,
            )
            return merged[None]

        out = jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(P("pod"), P("pod"), P("pod")),
            out_specs=P("pod"), check_vma=False,
        ))(per_pod, losses, live)
        return np.asarray(out)

    # average, uncompressed, all live: anchor + mean(delta)
    out = run("average", "none", False)
    expect = np.asarray(anchor) + np.mean(np.asarray(per_pod) - np.asarray(anchor), 0)
    for pod in range(4):
        np.testing.assert_allclose(out[pod], expect, rtol=1e-5)
    print("outer average OK")

    # average with liveness mask: dead pod 2 excluded
    out = run("average", "none", True)
    deltas = np.asarray(per_pod) - np.asarray(anchor)
    expect = np.asarray(anchor) + deltas[[0, 1, 3]].mean(axis=0)
    np.testing.assert_allclose(out[0], expect, rtol=1e-5)
    print("outer average + liveness OK")

    # int8 compression: close to uncompressed (quantization tolerance)
    out_q = run("average", "int8", False)
    expect = np.asarray(anchor) + deltas.mean(axis=0)
    err = np.abs(out_q[0] - expect).max()
    scale = np.abs(deltas).max() / 127.0
    assert err <= 4 * scale + 1e-6, (err, scale)
    print(f"outer int8 average OK (max err {err:.2e} <= 4*lsb {4*scale:.2e})")

    # miniloss_global: pod 1 (loss .2) wins everywhere
    out = run("miniloss_global", "none", False)
    np.testing.assert_allclose(out[0], np.asarray(per_pod)[1], rtol=1e-5)
    print("outer miniloss_global OK")

    # miniloss_global + liveness: among live pods only
    out = run("miniloss_global", "none", True)
    np.testing.assert_allclose(out[0], np.asarray(per_pod)[1], rtol=1e-5)
    print("outer miniloss_global + liveness OK")

    # random: result equals some pod's params, same on every pod
    out = run("random", "none", False)
    assert any(np.allclose(out[0], np.asarray(per_pod)[w]) for w in range(4))
    for pod in range(1, 4):
        np.testing.assert_allclose(out[pod], out[0])
    print("outer random OK")


def check_device_pipeline():
    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    tcfg = transe.TransEConfig(
        n_entities=kg.n_entities, n_relations=kg.n_relations, dim=8,
        learning_rate=0.05,
    )
    mesh = jax.make_mesh((W,), ("workers",))
    for paradigm, merge_every in (("sgd", 1), ("sgd", 2), ("bgd", 1)):
        cfg_v = mapreduce.MapReduceConfig(
            n_workers=W, paradigm=paradigm, backend="vmap", batch_size=16,
            pipeline="device",
            schedule=mapreduce.EpochSchedule(
                block_epochs=4, merge_every=merge_every))
        res_v = mapreduce.train(kg, tcfg, cfg_v, epochs=4, seed=0)
        cfg_s = dataclasses.replace(cfg_v, backend="shard_map")
        res_s = mapreduce.train(kg, tcfg, cfg_s, epochs=4, seed=0, mesh=mesh)
        np.testing.assert_allclose(
            np.asarray(res_s.loss_history), np.asarray(res_v.loss_history),
            rtol=1e-3, err_msg=f"device {paradigm} K={merge_every} losses")
        for k in ("ent", "rel"):
            np.testing.assert_allclose(
                np.asarray(res_s.params[k]), np.asarray(res_v.params[k]),
                rtol=1e-3, atol=1e-5,
                err_msg=f"device {paradigm} K={merge_every} table {k}")
        print(f"device pipeline {paradigm} K={merge_every}: "
              "shard_map == vmap  OK")


def check_device_eval():
    from repro.core import eval_device
    from repro.core.models import get_model

    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    tcfg = transe.TransEConfig(
        n_entities=kg.n_entities, n_relations=kg.n_relations, dim=8)
    model = get_model("transe")
    params = transe.init_params(jax.random.PRNGKey(2), tcfg)
    masks = kg.eval_filter_candidates()
    mesh = jax.make_mesh((W,), ("workers",))

    ref = eval_device.entity_ranks_device(
        params, kg.test, "l1", masks, model=model, n_workers=W)
    for workers in (W, 2 * W):       # 2W = two worker blocks per shard
        got = eval_device.entity_ranks_device(
            params, kg.test, "l1", masks, model=model, n_workers=workers,
            backend="shard_map", mesh=mesh)
        for grp in ("raw_ranks", "filtered_ranks"):
            for side in ("tail", "head"):
                np.testing.assert_array_equal(
                    got[grp][side], ref[grp][side],
                    err_msg=f"device eval W={workers} {grp}/{side}")
        print(f"device eval W={workers}: shard_map == vmap (exact)  OK")

    try:
        eval_device.entity_ranks_device(
            params, kg.test, "l1", masks, model=model, n_workers=W + 1,
            backend="shard_map", mesh=mesh)
    except ValueError as e:
        assert "does not divide over mesh axis" in str(e), e
        print("device eval W not dividing mesh axis raises  OK")
    else:
        raise AssertionError("indivisible worker count did not raise")


def check_repartition():
    """Re-partitioning across workers on device: the shard_map path
    (all_gather + per-worker slice of the global permutation) must equal
    the vmap path (direct permutation of the stacked partition)."""
    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    tcfg = transe.TransEConfig(
        n_entities=kg.n_entities, n_relations=kg.n_relations, dim=8,
        learning_rate=0.05,
    )
    mesh = jax.make_mesh((W,), ("workers",))
    cfg_v = mapreduce.MapReduceConfig(
        n_workers=W, backend="vmap", batch_size=16, pipeline="device",
        schedule=mapreduce.EpochSchedule(
            block_epochs=2, repartition_every=2))
    res_v = mapreduce.train(kg, tcfg, cfg_v, epochs=6, seed=0)
    cfg_s = dataclasses.replace(cfg_v, backend="shard_map")
    res_s = mapreduce.train(kg, tcfg, cfg_s, epochs=6, seed=0, mesh=mesh)
    np.testing.assert_allclose(
        np.asarray(res_s.loss_history), np.asarray(res_v.loss_history),
        rtol=1e-3, err_msg="repartition losses")
    for k in ("ent", "rel"):
        np.testing.assert_allclose(
            np.asarray(res_s.params[k]), np.asarray(res_v.params[k]),
            rtol=1e-3, atol=1e-5, err_msg=f"repartition table {k}")
    print("device pipeline repartition_every=2: shard_map == vmap  OK")


def check_inloop_eval():
    """The in-loop eval trace from a shard_map training run: identical
    boundary structure to vmap, metric curve equal up to the collective
    reduction-order tolerance of the trained params themselves."""
    from repro import kg as kg_api

    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    mesh = jax.make_mesh((W,), ("workers",))
    kw = dict(model="transe", paradigm="sgd", n_workers=W, dim=8,
              learning_rate=0.05, batch_size=16, epochs=4, seed=0,
              pipeline="device", block_epochs=4, eval_every=2)
    res_v = kg_api.fit(kg, **kw)
    res_s = kg_api.fit(kg, backend="shard_map", mesh=mesh, **kw)
    assert res_v.trace.epochs() == res_s.trace.epochs() == [1, 3]
    assert ([e.merge_round for e in res_v.trace.entries]
            == [e.merge_round for e in res_s.trace.entries])
    np.testing.assert_allclose(
        res_s.trace.values(), res_v.trace.values(), rtol=0.05,
        err_msg="in-loop metric curve")
    # and each backend's trace is exactly its own post-hoc eval
    post = kg_api.evaluate(res_s.params, "transe", kg, engine="device",
                           n_workers=W)
    assert post == res_s.trace.entries[-1].metrics
    print("in-loop eval trace: shard_map == vmap (tolerance) "
          "and == post-hoc (exact)  OK")


def check_kb_resume_serve():
    """Checkpoint/resume and the serving engine under shard_map: resume is
    bit-identical within the backend, and the sharded query engine's
    top-k equals the single-device engine exactly."""
    import tempfile

    from repro import kg as kg_api
    from repro.serve.kg_engine import KGQueryEngine

    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    mesh = jax.make_mesh((W,), ("workers",))
    kw = dict(model="transe", n_workers=W, dim=8, learning_rate=0.05,
              batch_size=16, seed=0, pipeline="device", block_epochs=2,
              backend="shard_map", mesh=mesh)
    full = kg_api.fit(kg, epochs=4, **kw)
    d = tempfile.mkdtemp(prefix="kb_resume_")
    kg_api.fit(kg, epochs=2, ckpt_dir=d, checkpoint_every=2,
               sync_checkpoints=True, **kw)
    resumed = kg_api.fit(kg, epochs=4, ckpt_dir=d, resume=True, **kw)
    for k in ("ent", "rel"):
        np.testing.assert_array_equal(
            np.asarray(resumed.params[k]), np.asarray(full.params[k]),
            err_msg=f"shard_map resume table {k}")
    assert resumed.loss_history == full.loss_history
    print("shard_map checkpoint-resume: bit-identical  OK")

    params = {k: np.asarray(v) for k, v in full.params.items()}
    h, r = kg.test[:32, 0], kg.test[:32, 1]
    exclude = kg.known_candidate_masks(
        np.stack([h, r], axis=1), "tail")
    ref_eng = KGQueryEngine("transe", params)
    shard_eng = KGQueryEngine(
        "transe", params, n_workers=W, backend="shard_map", mesh=mesh)
    for label, q_kw in (("raw", {}), ("filtered", {"exclude": exclude})):
        ref = ref_eng.query_tails(h, r, k=10, **q_kw)
        got = shard_eng.query_tails(h, r, k=10, **q_kw)
        np.testing.assert_array_equal(
            got.ids, ref.ids, err_msg=f"serve {label} ids")
        np.testing.assert_array_equal(
            got.energies, ref.energies, err_msg=f"serve {label} energies")
    ref = ref_eng.query_relations(kg.test[:32, 0], kg.test[:32, 2], k=3)
    got = shard_eng.query_relations(kg.test[:32, 0], kg.test[:32, 2], k=3)
    np.testing.assert_array_equal(got.ids, ref.ids)
    print("serve engine: shard_map == vmap (exact, raw + filtered)  OK")


def check_kg_server():
    """The live serving tier on a sharded backend: a KGServer whose
    tenant engine runs shard_map across W workers forms waves, pads them
    to buckets, and still answers bit-identically to the single-device
    engine — and the warmed buckets never recompile."""
    from repro.core.models import KGConfig, get_model
    from repro.kb import KnowledgeBase
    from repro.serve import KGServer
    from repro.serve.kg_engine import KGQueryEngine

    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    mesh = jax.make_mesh((W,), ("workers",))
    model = get_model("transe")
    params = model.init_params(
        jax.random.PRNGKey(3),
        KGConfig(n_entities=200, n_relations=5, dim=8))
    kb = KnowledgeBase(model, params, graph=kg, norm="l1")
    ref_eng = KGQueryEngine("transe", {k: np.asarray(v)
                                       for k, v in params.items()})
    server = KGServer(kb, max_batch=4, max_wait_us=5000, default_k=10,
                      n_workers=W, backend="shard_map", mesh=mesh)
    server.warmup(kinds=("tails",))
    try:
        for size, filtered in ((1, False), (3, True), (4, False)):
            rows = kg.test[10:10 + size]
            h, r = rows[:, 0], rows[:, 1]
            server.pause()
            futs = [server.submit("tails", hh, rr, filtered=filtered)
                    for hh, rr in zip(h, r)]
            server.resume()
            answers = [f.result(timeout=60) for f in futs]
            if filtered:
                ref = kb.query_tails(h, r, k=10, filtered=True)
            else:
                ref = ref_eng.query_tails(h, r, k=10)
            for i, ans in enumerate(answers):
                np.testing.assert_array_equal(
                    ans.ids, ref.ids[i],
                    err_msg=f"server wave={size} filtered={filtered} ids")
                np.testing.assert_array_equal(
                    ans.energies, ref.energies[i],
                    err_msg=f"server wave={size} energies")
        assert server.stats().steady_recompiles == 0, server.stats()
    finally:
        server.stop()
    print("KGServer: shard_map waves == single-device engine (exact), "
          "0 steady recompiles  OK")


def check_sparse_transport():
    """The delta Reduce at real W=8: every backend x transport combination
    lands on the same bits (the collective sparse path reconstructs the
    same candidate union and merge arithmetic as the stacked paths)."""
    from repro import kg as kg_api

    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    mesh = jax.make_mesh((W,), ("workers",))
    for merge_every in (1, 2):
        kw = dict(model="transe", paradigm="sgd", n_workers=W, dim=8,
                  learning_rate=0.05, batch_size=16, epochs=4, seed=0,
                  pipeline="device", block_epochs=2, merge_every=merge_every)
        ref = kg_api.fit(kg, merge_transport="dense", **kw)
        shard_ref = kg_api.fit(kg, merge_transport="dense",
                               backend="shard_map", mesh=mesh, **kw)
        runs = {
            "vmap/sparse": kg_api.fit(kg, merge_transport="sparse", **kw),
            "shard_map/sparse": kg_api.fit(
                kg, merge_transport="sparse", backend="shard_map",
                mesh=mesh, **kw),
        }
        for label, res in runs.items():
            for k in ("ent", "rel"):
                np.testing.assert_array_equal(
                    np.asarray(res.params[k]), np.asarray(ref.params[k]),
                    err_msg=f"sparse transport K={merge_every} "
                            f"{label} table {k}")
            # the *params* contract is bitwise; the reported loss is a
            # psum-averaged diagnostic whose rounding shifts with the
            # compiled program (same tolerance story as
            # check_device_pipeline), so vmap is exact and shard_map is
            # near-exact
            if "shard_map" in label:
                np.testing.assert_allclose(
                    res.loss_history, shard_ref.loss_history, rtol=1e-6,
                    err_msg=f"K={merge_every} {label} losses")
            else:
                assert res.loss_history == ref.loss_history, (
                    f"K={merge_every} {label} losses")
        print(f"sparse transport K={merge_every}: sparse params == dense "
              "params across backends (exact)  OK")


def check_sharded_tables():
    """Sharded entity tables at real W=8: training, eval, and serving are
    each bit-identical to the replicated layout on a real mesh — the
    tentpole's exactness bar where the collectives actually run."""
    from repro import kg as kg_api
    from repro.core import eval_device
    from repro.core.models import KGConfig, get_model
    from repro.serve.kg_engine import KGQueryEngine

    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    mesh = jax.make_mesh((W,), ("workers",))

    for merge_every in (1, 2):
        kw = dict(model="transe", paradigm="sgd", n_workers=W, dim=8,
                  learning_rate=0.05, batch_size=16, epochs=4, seed=0,
                  pipeline="device", block_epochs=2,
                  merge_every=merge_every, merge_transport="sparse")
        ref = kg_api.fit(kg, backend="shard_map", mesh=mesh, **kw)
        got = kg_api.fit(kg, backend="shard_map", mesh=mesh,
                         table_sharding="sharded", **kw)
        vm = kg_api.fit(kg, table_sharding="sharded", **kw)
        # the residency claim, not just the math: the entity table must
        # *rest* row-sharded (~1/W rows on each device) after the run,
        # while the tiny relation table (5 rows < W) stays replicated
        ent_spec = got.params["ent"].sharding.spec
        assert tuple(ent_spec) == ("workers",), (
            f"entity table rests {ent_spec}, expected row-sharded")
        rows = sorted(s.data.shape[0]
                      for s in got.params["ent"].addressable_shards)
        assert rows == [200 // W] * W, f"per-device ent rows {rows}"
        assert tuple(got.params["rel"].sharding.spec) == (), (
            "relation table should rest replicated")
        for k in ("ent", "rel"):
            np.testing.assert_array_equal(
                np.asarray(got.params[k]), np.asarray(ref.params[k]),
                err_msg=f"sharded train K={merge_every} shard_map table {k}")
            np.testing.assert_array_equal(
                np.asarray(vm.params[k]), np.asarray(ref.params[k]),
                err_msg=f"sharded train K={merge_every} vmap table {k}")
        print(f"sharded tables K={merge_every}: sharded == replicated "
              "params across backends (exact)  OK")

    model = get_model("transe")
    params = model.init_params(
        jax.random.PRNGKey(2),
        KGConfig(n_entities=200, n_relations=5, dim=8))
    masks = kg.eval_filter_candidates()
    ref = eval_device.entity_ranks_device(
        params, kg.test, "l1", masks, model=model, n_workers=W)
    got = eval_device.entity_ranks_device(
        params, kg.test, "l1", masks, model=model, n_workers=W,
        backend="shard_map", mesh=mesh, table_sharding="sharded")
    for grp in ("raw_ranks", "filtered_ranks"):
        for side in ("tail", "head"):
            np.testing.assert_array_equal(
                got[grp][side], ref[grp][side],
                err_msg=f"sharded eval {grp}/{side}")
    print("sharded eval: shard-local scan == replicated (exact)  OK")

    h, r = kg.test[:32, 0], kg.test[:32, 1]
    exclude = kg.known_candidate_masks(np.stack([h, r], axis=1), "tail")
    ref_eng = KGQueryEngine("transe", params, n_workers=W)
    shard_eng = KGQueryEngine(
        "transe", params, n_workers=W, backend="shard_map", mesh=mesh,
        table_sharding="sharded")
    for label, q_kw in (("raw", {}), ("filtered", {"exclude": exclude})):
        for k in (10, 40):           # 40 > R=25: the local-kk cut
            a = ref_eng.query_tails(h, r, k=k, **q_kw)
            b = shard_eng.query_tails(h, r, k=k, **q_kw)
            np.testing.assert_array_equal(
                b.ids, a.ids, err_msg=f"sharded serve {label} k={k} ids")
            np.testing.assert_array_equal(
                b.energies, a.energies,
                err_msg=f"sharded serve {label} k={k} energies")
    print("sharded serve: shard-local top-k == replicated (exact)  OK")


def check_bounded_staleness():
    """Bounded-staleness Reduce (staleness=S) at real W=8: the stale
    schedule runs on a real mesh with the params bitwise-equal to the vmap
    simulation (dense and sparse transports, sharded tables), and the
    reported loss within the usual collective tolerance."""
    from repro import kg as kg_api

    kg = kg_lib.synthetic_kg(0, n_entities=200, n_relations=5, n_triplets=2000)
    mesh = jax.make_mesh((W,), ("workers",))
    for extra in ({}, {"merge_transport": "sparse"},
                  {"merge_transport": "sparse", "table_sharding": "sharded"}):
        kw = dict(model="transe", paradigm="sgd", n_workers=W, dim=8,
                  learning_rate=0.05, batch_size=16, epochs=8, seed=0,
                  pipeline="device", block_epochs=4, merge_every=2,
                  staleness=2, **extra)
        res_v = kg_api.fit(kg, **kw)
        res_s = kg_api.fit(kg, backend="shard_map", mesh=mesh, **kw)
        for k in ("ent", "rel"):
            np.testing.assert_array_equal(
                np.asarray(res_s.params[k]), np.asarray(res_v.params[k]),
                err_msg=f"staleness {extra} table {k}")
        np.testing.assert_allclose(
            np.asarray(res_s.loss_history), np.asarray(res_v.loss_history),
            rtol=1e-6, err_msg=f"staleness {extra} losses")
        label = extra.get("merge_transport", "dense")
        if extra.get("table_sharding") == "sharded":
            label += "/sharded"
        print(f"bounded staleness S=2 ({label}): shard_map == vmap "
              "(params exact)  OK")


if __name__ == "__main__":
    check_engine()
    check_outer_merge()
    check_device_pipeline()
    check_device_eval()
    check_repartition()
    check_inloop_eval()
    check_kb_resume_serve()
    check_kg_server()
    check_sparse_transport()
    check_sharded_tables()
    check_bounded_staleness()
    print("ALL MULTIDEVICE CHECKS PASSED")
