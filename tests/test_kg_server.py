"""Tests for the live serving tier (serve/server.py — ``KGServer``).

The serving tier's determinism contract: a served answer is
**bit-identical** to calling the bound artifact's ``KGQueryEngine``
directly with the same query — whatever wave the continuous batcher
formed around it, whichever power-of-two bucket padded it, whether it
came from the LRU answer cache or a fresh compiled wave, and on
whichever side of a zero-downtime ``swap()`` it was admitted.  Plus the
shape story: after ``warmup()``, a mixed-size query stream triggers zero
steady-state recompiles; and the cache story: a swap that changes the
artifact fingerprint invalidates the answer cache, one that doesn't
keeps it.
"""
import threading
import warnings

import jax
import numpy as np
import pytest

from repro.core.models import KGConfig, get_model
from repro.data import kg as kg_lib
from repro.kb import KnowledgeBase
from repro.serve import KGServer

MAX_BATCH = 8
WAIT_US = 5000


def _make_kb(graph, seed: int = 0) -> KnowledgeBase:
    model = get_model("transe")
    cfg = KGConfig(n_entities=graph.n_entities,
                   n_relations=graph.n_relations, dim=8)
    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    return KnowledgeBase(model, params, graph=graph, norm="l1")


@pytest.fixture(scope="module")
def kb(tiny_kg):
    return _make_kb(tiny_kg, seed=0)


@pytest.fixture(scope="module")
def uniq(tiny_kg):
    """Test-split indices with pairwise-distinct (h, r) — so tests that
    count cache hits/misses never collide on a duplicated query pair."""
    pairs = tiny_kg.test[:, :2]
    _, first = np.unique(pairs, axis=0, return_index=True)
    return np.sort(first)


@pytest.fixture()
def server(kb):
    srv = KGServer(kb, max_batch=MAX_BATCH, max_wait_us=WAIT_US,
                   default_k=10, warm=True)
    yield srv
    srv.stop()


def _wave(server, kind, a_ids, b_ids, **kw):
    """Submit a batch while admission is paused, then release it — the
    batcher admits exactly this set as one wave (sizes <= max_batch)."""
    server.pause()
    futs = [server.submit(kind, a, b, **kw)
            for a, b in zip(a_ids, b_ids)]
    server.resume()
    return [f.result(timeout=30) for f in futs]


# ---------------------------------------------------------------------------
# Answer parity: cache hit/miss, every bucket size, pad slots
# ---------------------------------------------------------------------------

def test_single_query_parity_and_cache(server, kb, tiny_kg, uniq):
    eng = kb.engine()
    rows = tiny_kg.test[uniq[:4]]
    for h, r, _ in rows:
        ans = server.query_tails(h, r)
        direct = eng.query_tails([h], [r], k=10)
        assert not ans.cached
        assert ans.fingerprint == kb.fingerprint()
        np.testing.assert_array_equal(ans.ids, direct.ids[0])
        np.testing.assert_array_equal(ans.energies, direct.energies[0])
    before = server.stats()
    for h, r, _ in rows:            # identical queries: all cache hits,
        ans = server.query_tails(h, r)   # answers still bit-identical
        direct = eng.query_tails([h], [r], k=10)
        assert ans.cached
        np.testing.assert_array_equal(ans.ids, direct.ids[0])
        np.testing.assert_array_equal(ans.energies, direct.energies[0])
    after = server.stats()
    assert after.cache_hits - before.cache_hits == len(rows)
    assert after.cache_misses == before.cache_misses
    assert after.waves == before.waves      # hits never reach the batcher


@pytest.mark.parametrize("size", [1, 2, 3, 4, 5, 8])
def test_wave_parity_every_bucket(server, kb, tiny_kg, uniq, size):
    """One wave per size: every bucket (1, 2, 4, 8), including partially
    padded ones, answers bit-identically to a direct engine batch."""
    eng = kb.engine()
    rows = tiny_kg.test[uniq[10:10 + size]]
    h, r = rows[:, 0], rows[:, 1]
    before = server.stats()
    answers = _wave(server, "tails", h, r, k=7)
    direct = eng.query_tails(h, r, k=7)
    for i, ans in enumerate(answers):
        np.testing.assert_array_equal(ans.ids, direct.ids[i])
        np.testing.assert_array_equal(ans.energies, direct.energies[i])
    after = server.stats()
    assert after.waves - before.waves == 1
    bucket = 1 << (size - 1).bit_length() if size > 1 else 1
    assert after.bucket_waves.get(bucket, 0) == \
        before.bucket_waves.get(bucket, 0) + 1


def test_pad_slots_do_not_leak(server, kb, tiny_kg, uniq):
    """A wave of 3 rides a bucket of 4; each answer equals the engine's
    answer for a batch of exactly one — pad rows never touch live rows."""
    eng = kb.engine()
    rows = tiny_kg.test[uniq[20:23]]
    answers = _wave(server, "tails", rows[:, 0], rows[:, 1], k=6)
    for ans, (h, r, _) in zip(answers, rows):
        direct = eng.query_tails([h], [r], k=6)
        np.testing.assert_array_equal(ans.ids, direct.ids[0])
        np.testing.assert_array_equal(ans.energies, direct.energies[0])


def test_heads_and_relations_parity(server, kb, tiny_kg, uniq):
    eng = kb.engine()
    rows = tiny_kg.test[uniq[25:30]]
    h, r, t = rows[:, 0], rows[:, 1], rows[:, 2]
    heads = _wave(server, "heads", t, r, k=9)
    direct = eng.query_heads(t, r, k=9)
    for i, ans in enumerate(heads):
        np.testing.assert_array_equal(ans.ids, direct.ids[i])
        np.testing.assert_array_equal(ans.energies, direct.energies[i])
    rels = _wave(server, "relations", h, t, k=3)
    direct = eng.query_relations(h, t, k=3)
    for i, ans in enumerate(rels):
        np.testing.assert_array_equal(ans.ids, direct.ids[i])
        np.testing.assert_array_equal(ans.energies, direct.energies[i])


def test_filtered_and_explicit_exclusion_parity(server, kb, tiny_kg, uniq):
    rows = tiny_kg.test[uniq[30:34]]
    h, r = rows[:, 0], rows[:, 1]
    answers = _wave(server, "tails", h, r, k=8, filtered=True)
    direct = kb.query_tails(h, r, k=8, filtered=True)
    for i, ans in enumerate(answers):
        np.testing.assert_array_equal(ans.ids, direct.ids[i])
        np.testing.assert_array_equal(ans.energies, direct.energies[i])
    # explicit blacklist: excluded ids never appear, answers match the
    # engine given the same padded exclusion row
    eng = kb.engine()
    block = tuple(int(x) for x in direct.ids[0][:3])
    ans = server.query_tails(h[0], r[0], k=8, exclude=block)
    ex = np.array([sorted(block)], np.int32)
    ref = eng.query_tails([h[0]], [r[0]], k=8, exclude=ex)
    np.testing.assert_array_equal(ans.ids, ref.ids[0])
    np.testing.assert_array_equal(ans.energies, ref.energies[0])
    assert not set(block) & set(ans.ids.tolist())


# ---------------------------------------------------------------------------
# Bucketing: zero steady-state recompiles across a mixed-size stream
# ---------------------------------------------------------------------------

def test_mixed_stream_zero_steady_recompiles(server, tiny_kg, uniq):
    """After warmup, a stream mixing every wave size (and filtered and
    unfiltered exclusion shapes) at the warmed k never recompiles."""
    idx = 0
    for size in (1, 3, 8, 2, 5, 4, 7, 6, 1, 8):
        rows = tiny_kg.test[uniq[idx:idx + size]]
        idx += size
        _wave(server, "tails", rows[:, 0], rows[:, 1],
              filtered=bool(size % 2))
    st = server.stats()
    assert st.steady_recompiles == 0, st
    # (warm_compiles may be 0 here: the jit cache is process-global, so
    # earlier tests can have pre-compiled every shape warmup targets)


# ---------------------------------------------------------------------------
# Hot swap: drain old, admit new, exactly one artifact per answer
# ---------------------------------------------------------------------------

def test_swap_mid_wave_drains_against_old_artifact(kb, tiny_kg, uniq):
    """A swap landing while a wave is in flight: the wave already bound
    the old artifact and answers from it; the next admission sees the
    new one.  Both sides are bit-checked against their own engine."""
    kb2 = _make_kb(tiny_kg, seed=1)
    assert kb2.fingerprint() != kb.fingerprint()
    srv = KGServer(kb, max_batch=4, max_wait_us=WAIT_US, default_k=10,
                   warm=True)
    try:
        swapped = threading.Event()

        def mid_wave_swap(kind, size, bucket, tenant, fp):
            if not swapped.is_set():
                swapped.set()
                srv.swap(kb2)       # flips the pointer mid-flight

        srv.on_wave_start = mid_wave_swap
        rows = tiny_kg.test[uniq[40:43]]
        h, r = rows[:, 0], rows[:, 1]
        old_wave = _wave(srv, "tails", h, r)
        assert swapped.is_set()
        direct_old = kb.engine().query_tails(h, r, k=10)
        for i, ans in enumerate(old_wave):
            assert ans.fingerprint == kb.fingerprint()
            np.testing.assert_array_equal(ans.ids, direct_old.ids[i])
            np.testing.assert_array_equal(
                ans.energies, direct_old.energies[i])
        # everything admitted after the flip answers from the new KB
        new_ans = srv.query_tails(h[0], r[0])
        direct_new = kb2.engine().query_tails([h[0]], [r[0]], k=10)
        assert new_ans.fingerprint == kb2.fingerprint()
        assert not new_ans.cached   # old-KB answers were invalidated
        np.testing.assert_array_equal(new_ans.ids, direct_new.ids[0])
        np.testing.assert_array_equal(
            new_ans.energies, direct_new.energies[0])
    finally:
        srv.on_wave_start = None
        srv.stop()


def test_queued_requests_admit_the_new_artifact(kb, tiny_kg, uniq):
    """Requests still queued (not yet admitted) when swap() flips the
    pointer are answered by the NEW artifact — binding happens at
    admission, so no answer ever mixes artifacts."""
    kb2 = _make_kb(tiny_kg, seed=2)
    srv = KGServer(kb, max_batch=4, max_wait_us=WAIT_US, default_k=10,
                   warm=True)
    try:
        rows = tiny_kg.test[uniq[45:47]]
        srv.pause()
        futs = [srv.submit("tails", h, r) for h, r, _ in rows]
        srv.swap(kb2)
        srv.resume()
        direct = kb2.engine().query_tails(rows[:, 0], rows[:, 1], k=10)
        for i, f in enumerate(futs):
            ans = f.result(timeout=30)
            assert ans.fingerprint == kb2.fingerprint()
            np.testing.assert_array_equal(ans.ids, direct.ids[i])
            np.testing.assert_array_equal(ans.energies, direct.energies[i])
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# Answer cache vs artifact identity
# ---------------------------------------------------------------------------

def test_swap_with_new_fingerprint_invalidates_cache(kb, tiny_kg, uniq):
    """The ISSUE's guard: a swap() to a KB whose graph (here: graph AND
    params) fingerprint differs must invalidate the LRU answer cache."""
    other_graph = kg_lib.synthetic_kg(7, n_entities=tiny_kg.n_entities,
                                      n_relations=tiny_kg.n_relations,
                                      n_triplets=800)
    kb_other = _make_kb(other_graph, seed=3)
    assert other_graph.fingerprint() != tiny_kg.fingerprint()
    assert kb_other.fingerprint() != kb.fingerprint()
    srv = KGServer(kb, max_batch=4, max_wait_us=WAIT_US, default_k=10,
                   warm=True)
    try:
        h, r, _ = tiny_kg.test[uniq[50]]
        assert not srv.query_tails(h, r).cached
        assert srv.query_tails(h, r).cached          # primed
        srv.swap(kb_other)
        st = srv.stats()
        assert st.swaps == 1 and st.cache_invalidations == 1
        ans = srv.query_tails(h, r)                  # miss again, new KB
        assert not ans.cached
        assert ans.fingerprint == kb_other.fingerprint()
        direct = kb_other.engine().query_tails([h], [r], k=10)
        np.testing.assert_array_equal(ans.ids, direct.ids[0])
    finally:
        srv.stop()


def test_swap_with_same_fingerprint_keeps_cache(kb, tiny_kg, uniq):
    """Identical content => identical fingerprint => the cache survives
    the swap (the keys could never go stale)."""
    twin = KnowledgeBase(kb.model, kb.params, graph=kb.graph, norm=kb.norm)
    assert twin.fingerprint() == kb.fingerprint()
    srv = KGServer(kb, max_batch=4, max_wait_us=WAIT_US, default_k=10,
                   warm=True)
    try:
        h, r, _ = tiny_kg.test[uniq[51]]
        assert not srv.query_tails(h, r).cached
        srv.swap(twin)
        st = srv.stats()
        assert st.swaps == 1 and st.cache_invalidations == 0
        assert srv.query_tails(h, r).cached
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# Multi-KB tenancy, stats, error surface
# ---------------------------------------------------------------------------

def test_multi_tenant_isolation(kb, tiny_kg, uniq):
    kb_b = _make_kb(tiny_kg, seed=4)
    srv = KGServer(kb, max_batch=4, max_wait_us=WAIT_US, default_k=10,
                   warm=True)
    try:
        srv.add_tenant("b", kb_b)
        h, r, _ = tiny_kg.test[uniq[52]]
        a = srv.query_tails(h, r)
        b = srv.query_tails(h, r, tenant="b")
        assert a.fingerprint == kb.fingerprint()
        assert b.fingerprint == kb_b.fingerprint()
        assert not b.cached     # cache keys are fingerprint-scoped
        np.testing.assert_array_equal(
            a.ids, kb.engine().query_tails([h], [r], k=10).ids[0])
        np.testing.assert_array_equal(
            b.ids, kb_b.engine().query_tails([h], [r], k=10).ids[0])
    finally:
        srv.stop()


def test_stats_and_error_surface(kb, tiny_kg, uniq):
    srv = KGServer(kb, max_batch=4, max_wait_us=WAIT_US, default_k=10,
                   slo_p99_ms=60_000.0)
    try:
        with pytest.raises(ValueError, match="kind"):
            srv.submit("tail", 0, 0)
        with pytest.raises(ValueError, match="exclusion"):
            srv.submit("relations", 0, 0, filtered=True)
        with pytest.raises(KeyError, match="tenant"):
            srv.submit("tails", 0, 0, tenant="nope")
        h, r, _ = tiny_kg.test[uniq[53]]
        srv.query_tails(h, r)
        st = srv.stats()
        assert st.completed == st.requests == 1
        assert st.p50_ms <= st.p99_ms
        assert st.slo_met is True   # a minute of headroom on one query
    finally:
        srv.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        srv.submit("tails", 0, 0)


# ---------------------------------------------------------------------------
# Recompile-gate counter: narrow fallback, loud, visible in stats (satellite)
# ---------------------------------------------------------------------------

def test_recompile_counter_reports_live_source(server):
    """stats() names the counter steady_recompiles was measured against —
    the jit cache when this jax exposes it, the shape registry otherwise."""
    from repro.serve import server as server_mod
    expected = ("jit-cache" if server_mod._engine_cache_size() is not None
                else "shape-registry")
    assert server.stats().recompile_counter == expected


def test_cache_size_fallback_is_narrow(monkeypatch):
    """None only for the missing/incompatible-``_cache_size`` jax surface;
    any other exception propagates.  The pre-fix bare except swallowed
    real engine bugs here, which made the recompile gate pass vacuously
    (``fresh`` looked like 0 forever)."""
    from repro.serve import kg_engine
    from repro.serve import server as server_mod

    class NoCacheSize:
        def __getattr__(self, name):
            raise AttributeError(name)

    monkeypatch.setattr(kg_engine, "_entity_topk_device", NoCacheSize())
    assert server_mod._engine_cache_size() is None

    class Exploding:
        @staticmethod
        def _cache_size():
            raise RuntimeError("real engine bug")

    monkeypatch.setattr(kg_engine, "_entity_topk_device", Exploding())
    with pytest.raises(RuntimeError, match="real engine bug"):
        server_mod._engine_cache_size()


def test_registry_fallback_warns_once_and_still_counts(kb, tiny_kg, uniq,
                                                       monkeypatch):
    """When the jit cache is unavailable the server says so (one
    warn_fresh per server, stats().recompile_counter flips) instead of
    silently weakening the gate — and the shape registry still catches a
    genuinely novel steady-state shape."""
    from repro.serve import server as server_mod
    monkeypatch.setattr(server_mod, "_engine_cache_size", lambda: None)
    with pytest.warns(UserWarning, match="first-seen-shape registry"):
        srv = KGServer(kb, max_batch=4, max_wait_us=WAIT_US, default_k=10,
                       warm=True)
    try:
        assert srv.stats().recompile_counter == "shape-registry"
        h, r, _ = tiny_kg.test[uniq[0]]
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            srv.query_tails(h, r)        # warmed shape: no recompile,
        assert not [w for w in rec       # and no second warning
                    if "shape registry" in str(w.message)]
        assert srv.stats().steady_recompiles == 0
        srv.query_tails(h, r, k=3)       # never-warmed k: fresh shape
        assert srv.stats().steady_recompiles >= 1
    finally:
        srv.stop()


def test_filtered_needs_graph(kb):
    bare = KnowledgeBase(kb.model, kb.params, graph=None, norm=kb.norm)
    srv = KGServer(bare, max_batch=2, max_wait_us=WAIT_US)
    try:
        with pytest.raises(ValueError, match="graph"):
            srv.submit("tails", 0, 0, filtered=True)
        srv.query_tails(0, 0)       # unfiltered serving needs no graph
    finally:
        srv.stop()
