"""Tests for optimizer / chunked CE / checkpointing / fault tolerance.

``hypothesis`` is optional: without it the property test is skipped and a
fixed-shape parametrized fallback runs the same check."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.train import checkpoint as ckpt_lib
from repro.train import losses, optimizer as opt_lib


class TestOptimizers:
    def quad_loss(self, p):
        return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)

    @pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
    def test_converges_on_quadratic(self, name):
        cfg = opt_lib.OptConfig(
            name=name, learning_rate=0.1, warmup_steps=0, decay_steps=10**6,
            weight_decay=0.0, grad_clip=0.0)
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        state = opt_lib.init(params, cfg)
        for _ in range(300):
            grads = jax.grad(self.quad_loss)(params)
            params, state, _ = opt_lib.apply(params, grads, state, cfg)
        assert float(self.quad_loss(params)) < 1e-2, name

    def test_adamw_matches_reference_math(self):
        cfg = opt_lib.OptConfig(name="adamw", learning_rate=1e-2,
                                warmup_steps=0, decay_steps=10**9,
                                min_lr_ratio=1.0, weight_decay=0.0,
                                grad_clip=0.0)
        p = {"w": jnp.asarray([[1.0, 2.0]])}
        g = {"w": jnp.asarray([[0.5, -0.5]])}
        state = opt_lib.init(p, cfg)
        new, state, _ = opt_lib.apply(p, g, state, cfg)
        # manual step 1: mhat = g, vhat = g^2 -> update = sign-ish
        expect = 1.0 - 1e-2 * 0.5 / (np.sqrt(0.25) + cfg.eps)
        np.testing.assert_allclose(np.asarray(new["w"])[0, 0], expect,
                                   rtol=1e-5)

    def test_grad_clip(self):
        g = {"w": jnp.full((10,), 100.0)}
        clipped, gnorm = opt_lib.clip_by_global_norm(g, 1.0)
        assert float(gnorm) == pytest.approx(np.sqrt(10 * 100.0 ** 2), rel=1e-5)
        total = np.sqrt(float(sum(jnp.sum(x ** 2)
                                  for x in jax.tree.leaves(clipped))))
        assert total == pytest.approx(1.0, rel=1e-4)

    def test_adafactor_memory_is_factored(self):
        cfg = opt_lib.OptConfig(name="adafactor", factored_min_dim=4)
        p = {"w": jnp.zeros((128, 256))}
        state = opt_lib.init(p, cfg)
        v = state["v"]["w"]
        assert "vr" in v and v["vr"].shape == (128,)
        assert v["vc"].shape == (256,)

    def test_lr_schedule_warmup_and_decay(self):
        cfg = opt_lib.OptConfig(learning_rate=1.0, warmup_steps=10,
                                decay_steps=100, min_lr_ratio=0.1)
        lr0 = float(opt_lib.lr_at(jnp.asarray(5), cfg))
        lr_full = float(opt_lib.lr_at(jnp.asarray(10), cfg))
        lr_end = float(opt_lib.lr_at(jnp.asarray(110), cfg))
        assert lr0 == pytest.approx(0.5, rel=1e-5)
        assert lr_full == pytest.approx(1.0, rel=1e-5)
        assert lr_end == pytest.approx(0.1, rel=1e-3)


class TestChunkedCE:
    def _unembed(self, V, d, seed=0):
        W = jax.random.normal(jax.random.PRNGKey(seed), (d, V)) * 0.1
        return lambda h: (h.astype(jnp.float32) @ W)

    def _check_chunked_equals_full(self, B, L, chunk, seed):
        d, V = 8, 32
        key = jax.random.PRNGKey(seed)
        h = jax.random.normal(key, (B, L, d))
        labels = jax.random.randint(key, (B, L), 0, V)
        # sprinkle IGNOREs
        labels = labels.at[:, -1].set(losses.IGNORE)
        fn = self._unembed(V, d, seed)
        a = losses.chunked_cross_entropy(h, labels, fn, chunk=chunk)
        b = losses.full_cross_entropy(h, labels, fn)
        np.testing.assert_allclose(float(a), float(b), rtol=1e-5)

    @pytest.mark.parametrize(
        "B,L,chunk,seed",
        [(1, 3, 1, 0), (2, 12, 5, 7), (3, 17, 64, 123), (2, 16, 16, 10**6)])
    def test_chunked_equals_full_fixed_shapes(self, B, L, chunk, seed):
        """Non-hypothesis fallback: always runs, fixed corpus of shapes."""
        self._check_chunked_equals_full(B, L, chunk, seed)

    if HAVE_HYPOTHESIS:
        @given(
            B=st.integers(1, 3), L=st.integers(3, 17),
            chunk=st.integers(1, 64), seed=st.integers(0, 10**6),
        )
        @settings(max_examples=20, deadline=None)
        def test_chunked_equals_full_any_chunk(self, B, L, chunk, seed):
            self._check_chunked_equals_full(B, L, chunk, seed)

    def test_gradients_match(self):
        d, V, B, L = 8, 32, 2, 12
        key = jax.random.PRNGKey(0)
        h = jax.random.normal(key, (B, L, d))
        labels = jax.random.randint(key, (B, L), 0, V)
        fn = self._unembed(V, d)
        ga = jax.grad(
            lambda x: losses.chunked_cross_entropy(x, labels, fn, chunk=5))(h)
        gb = jax.grad(
            lambda x: losses.full_cross_entropy(x, labels, fn))(h)
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                   rtol=1e-4, atol=1e-6)

    def test_all_ignored_is_zero(self):
        h = jnp.ones((1, 4, 8))
        labels = jnp.full((1, 4), losses.IGNORE)
        fn = self._unembed(32, 8)
        assert float(losses.chunked_cross_entropy(h, labels, fn, 2)) == 0.0

    def test_shift_labels(self):
        toks = jnp.asarray([[5, 6, 7]])
        lab = losses.shift_labels(toks)
        assert lab.tolist() == [[6, 7, losses.IGNORE]]


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"a": jax.random.normal(k, (4, 8)),
                "nested": {"b": jnp.arange(5, dtype=jnp.int32)}}

    def test_roundtrip(self, tmp_path):
        d = str(tmp_path / "ckpt")
        params = self._tree()
        opt = {"step": jnp.asarray(7), "m": self._tree(1)}
        ckpt_lib.save(d, 7, params, opt, extra={"foo": 1})
        tpl_p = jax.eval_shape(lambda: params)
        tpl_o = jax.eval_shape(lambda: opt)
        step, p2, o2, extra = ckpt_lib.restore(
            d, params_template=tpl_p, opt_template=tpl_o)
        assert step == 7 and extra == {"foo": 1}
        np.testing.assert_array_equal(np.asarray(p2["a"]),
                                      np.asarray(params["a"]))
        np.testing.assert_array_equal(np.asarray(o2["m"]["nested"]["b"]),
                                      np.asarray(opt["m"]["nested"]["b"]))

    def test_latest_and_keep(self, tmp_path):
        d = str(tmp_path / "ckpt")
        for s in (1, 2, 3, 4):
            ckpt_lib.save(d, s, self._tree(), keep=2)
        assert ckpt_lib.latest_step(d) == 4
        dirs = sorted(os.listdir(d))
        assert len([x for x in dirs if x.startswith("step_")]) == 2

    def test_async_save(self, tmp_path):
        d = str(tmp_path / "ckpt")
        saver = ckpt_lib.AsyncSaver()
        saver.save_async(d, 3, self._tree())
        saver.wait()
        assert ckpt_lib.latest_step(d) == 3

    def test_atomicity_no_tmp_considered(self, tmp_path):
        d = str(tmp_path / "ckpt")
        ckpt_lib.save(d, 1, self._tree())
        os.makedirs(os.path.join(d, "step_0000000009.tmp"))
        assert ckpt_lib.latest_step(d) == 1
