"""Unit + property tests for the Reduce-phase merge strategies (paper §3.1.2).

``hypothesis`` is optional: without it the property tests are skipped and
fixed-seed parametrized fallbacks run the same checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import merge


def mk(W=3, N=5, k=4, seed=0):
    rng = np.random.default_rng(seed)
    stacked = jnp.asarray(rng.normal(size=(W, N, k)).astype(np.float32))
    counts = jnp.asarray(rng.integers(0, 4, size=(W, N)).astype(np.float32))
    losses = jnp.asarray(rng.uniform(0, 2, size=(W, N)).astype(np.float32) * counts)
    worker_loss = jnp.asarray(rng.uniform(0.1, 1.0, size=(W,)).astype(np.float32))
    return stacked, counts, losses, worker_loss


class TestAverage:
    def test_average_all_is_plain_mean(self):
        stacked, counts, losses, wl = mk()
        out = merge.merge_stacked("average_all", stacked, counts, losses, wl)
        np.testing.assert_allclose(out, np.mean(np.asarray(stacked), axis=0), rtol=1e-6)

    def test_average_weights_by_touch_count(self):
        stacked = jnp.asarray(
            np.stack([np.full((2, 3), 1.0), np.full((2, 3), 5.0)]).astype(np.float32)
        )
        counts = jnp.asarray(np.array([[3.0, 0.0], [1.0, 0.0]], np.float32))
        losses = jnp.zeros_like(counts)
        wl = jnp.zeros((2,))
        out = np.asarray(merge.merge_stacked("average", stacked, counts, losses, wl))
        # key 0: (3*1 + 1*5)/4 = 2 ; key 1 untouched -> plain mean = 3
        np.testing.assert_allclose(out[0], 2.0, rtol=1e-6)
        np.testing.assert_allclose(out[1], 3.0, rtol=1e-6)


class TestMiniLoss:
    def test_global_picks_min_loss_worker(self):
        stacked, counts, losses, _ = mk()
        wl = jnp.asarray(np.array([0.5, 0.1, 0.9], np.float32))
        out = merge.merge_stacked("miniloss_global", stacked, counts, losses, wl)
        np.testing.assert_allclose(out, stacked[1])

    def test_perkey_picks_min_mean_loss_toucher(self):
        W, N, k = 2, 2, 3
        stacked = jnp.asarray(np.stack(
            [np.full((N, k), 1.0), np.full((N, k), 2.0)]).astype(np.float32))
        counts = jnp.asarray(np.array([[1.0, 1.0], [1.0, 1.0]], np.float32))
        # key 0: worker1 lower loss; key 1: worker0 lower loss
        losses = jnp.asarray(np.array([[0.9, 0.1], [0.2, 0.8]], np.float32))
        wl = jnp.zeros((2,))
        out = np.asarray(
            merge.merge_stacked("miniloss_perkey", stacked, counts, losses, wl)
        )
        np.testing.assert_allclose(out[0], 2.0)
        np.testing.assert_allclose(out[1], 1.0)

    def test_perkey_ignores_untouched_workers(self):
        stacked = jnp.asarray(np.stack(
            [np.full((1, 2), 7.0), np.full((1, 2), 9.0)]).astype(np.float32))
        counts = jnp.asarray(np.array([[0.0], [2.0]], np.float32))
        losses = jnp.asarray(np.array([[0.0], [5.0]], np.float32))  # toucher has loss
        out = np.asarray(merge.merge_stacked(
            "miniloss_perkey", stacked, counts, losses, jnp.zeros((2,))))
        np.testing.assert_allclose(out[0], 9.0)   # only worker 1 touched


class TestRandom:
    def test_selects_a_toucher(self):
        stacked, counts, losses, wl = mk(W=4, N=64, k=2, seed=3)
        out = np.asarray(merge.merge_stacked(
            "random", stacked, counts, losses, wl, key=jax.random.PRNGKey(0)))
        s, c = np.asarray(stacked), np.asarray(counts)
        for n in range(64):
            touchers = np.where(c[:, n] > 0)[0]
            cands = touchers if len(touchers) else np.arange(4)
            match = any(np.allclose(out[n], s[w, n]) for w in cands)
            assert match, f"key {n}: merged row is not any toucher's row"

    def test_deterministic_given_key(self):
        stacked, counts, losses, wl = mk(W=4, N=32, k=2, seed=5)
        a = merge.merge_stacked("random", stacked, counts, losses, wl,
                                key=jax.random.PRNGKey(7))
        b = merge.merge_stacked("random", stacked, counts, losses, wl,
                                key=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_needs_key(self):
        stacked, counts, losses, wl = mk()
        with pytest.raises(ValueError):
            merge.merge_stacked("random", stacked, counts, losses, wl)


def _check_average_between_min_and_max(W, N, k, seed):
    rng = np.random.default_rng(seed)
    stacked = jnp.asarray(rng.normal(size=(W, N, k)).astype(np.float32))
    counts = jnp.asarray(rng.integers(0, 3, size=(W, N)).astype(np.float32))
    out = np.asarray(merge.merge_stacked(
        "average", stacked, counts, jnp.zeros((W, N)), jnp.zeros((W,))))
    s = np.asarray(stacked)
    assert np.all(out <= s.max(axis=0) + 1e-5)
    assert np.all(out >= s.min(axis=0) - 1e-5)


def _check_identical_workers_merge_to_same(seed):
    """All strategies are the identity when worker copies agree."""
    rng = np.random.default_rng(seed)
    row = rng.normal(size=(6, 3)).astype(np.float32)
    stacked = jnp.asarray(np.stack([row] * 4))
    counts = jnp.asarray(rng.integers(0, 3, size=(4, 6)).astype(np.float32))
    losses = jnp.asarray(rng.uniform(size=(4, 6)).astype(np.float32))
    wl = jnp.asarray(rng.uniform(size=(4,)).astype(np.float32))
    for strat in merge.STRATEGIES:
        out = merge.merge_stacked(strat, stacked, counts, losses, wl,
                                  key=jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(out), row, rtol=1e-5,
                                   err_msg=strat)


def _check_average_worker_permutation_invariant(perm_seed):
    stacked, counts, losses, wl = mk(W=4, N=8, k=3, seed=11)
    perm = np.random.default_rng(perm_seed).permutation(4)
    a = merge.merge_stacked("average", stacked, counts, losses, wl)
    b = merge.merge_stacked(
        "average", stacked[perm], counts[perm], losses[perm], wl[perm]
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


class TestPropertiesFallback:
    """Non-hypothesis fallbacks: always run, fixed corpus of instances."""

    @pytest.mark.parametrize(
        "W,N,k,seed", [(2, 1, 1, 0), (3, 5, 4, 7), (5, 12, 6, 2**31 - 1)])
    def test_average_between_min_and_max(self, W, N, k, seed):
        _check_average_between_min_and_max(W, N, k, seed)

    @pytest.mark.parametrize("seed", [0, 42, 2**31 - 1])
    def test_identical_workers_merge_to_same(self, seed):
        _check_identical_workers_merge_to_same(seed)

    @pytest.mark.parametrize("perm_seed", [0, 13, 1000])
    def test_average_worker_permutation_invariant(self, perm_seed):
        _check_average_worker_permutation_invariant(perm_seed)


if HAVE_HYPOTHESIS:
    class TestProperties:
        @given(
            W=st.integers(2, 5), N=st.integers(1, 12), k=st.integers(1, 6),
            seed=st.integers(0, 2**31 - 1),
        )
        @settings(max_examples=25, deadline=None)
        def test_average_between_min_and_max(self, W, N, k, seed):
            _check_average_between_min_and_max(W, N, k, seed)

        @given(seed=st.integers(0, 2**31 - 1))
        @settings(max_examples=25, deadline=None)
        def test_identical_workers_merge_to_same(self, seed):
            _check_identical_workers_merge_to_same(seed)

        @given(perm_seed=st.integers(0, 1000))
        @settings(max_examples=15, deadline=None)
        def test_average_worker_permutation_invariant(self, perm_seed):
            _check_average_worker_permutation_invariant(perm_seed)
