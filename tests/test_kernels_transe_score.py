"""Pallas transe_score kernel vs pure-jnp oracle: shape/dtype sweeps +
differentiability of the fused loss (interpret mode; TPU is the target).

``hypothesis`` is optional: without it the property test is skipped and a
fixed-seed parametrized fallback runs the same check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import transe
from repro.kernels import ops, ref
from repro.kernels.transe_score import transe_score


def make_inputs(E, R, k, B, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    ent = jnp.asarray(rng.normal(size=(E, k)).astype(np.float32)).astype(dtype)
    rel = jnp.asarray(rng.normal(size=(R, k)).astype(np.float32)).astype(dtype)
    idx = jnp.asarray(
        np.stack(
            [
                rng.integers(0, E, B),
                rng.integers(0, R, B),
                rng.integers(0, E, B),
                rng.integers(0, E, B),
                rng.integers(0, E, B),
            ],
            axis=1,
        ).astype(np.int32)
    )
    return ent, rel, idx


@pytest.mark.parametrize("norm", ["l1", "l2"])
@pytest.mark.parametrize(
    "E,R,k,B",
    [
        (32, 4, 16, 8),
        (128, 8, 64, 32),
        (100, 3, 128, 17),    # non-power-of-2 table, odd batch
        (64, 2, 256, 1),      # single triplet
    ],
)
def test_matches_oracle_shapes(E, R, k, B, norm):
    ent, rel, idx = make_inputs(E, R, k, B)
    loss, dp, dn = transe_score(ent, rel, idx, margin=1.0, norm=norm,
                                interpret=True)
    rloss, rdp, rdn = ref.transe_score_ref(ent, rel, idx, 1.0, norm)
    np.testing.assert_allclose(loss, rloss, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dp, rdp, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dn, rdn, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    ent, rel, idx = make_inputs(64, 4, 32, 16, dtype=dtype)
    loss, _, _ = transe_score(ent, rel, idx, margin=2.0, norm="l1",
                              interpret=True)
    rloss, _, _ = ref.transe_score_ref(ent, rel, idx, 2.0, "l1")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(loss, rloss, rtol=tol, atol=tol)


def _check_random_instance(seed, margin, norm):
    ent, rel, idx = make_inputs(48, 5, 24, 12, seed=seed)
    loss, dp, dn = transe_score(ent, rel, idx, margin=margin, norm=norm,
                                interpret=True)
    rloss, rdp, rdn = ref.transe_score_ref(ent, rel, idx, margin, norm)
    np.testing.assert_allclose(loss, rloss, rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(loss) >= 0.0)       # hinge is nonnegative
    assert np.all(np.asarray(dp) >= 0.0) and np.all(np.asarray(dn) >= 0.0)


@pytest.mark.parametrize("norm", ["l1", "l2"])
@pytest.mark.parametrize("seed,margin", [(0, 0.1), (17, 1.0), (999, 4.0)])
def test_random_instances_fixed_seeds(seed, margin, norm):
    """Non-hypothesis fallback: always runs, fixed corpus of instances."""
    _check_random_instance(seed, margin, norm)


if HAVE_HYPOTHESIS:
    @given(
        seed=st.integers(0, 2**31 - 1),
        margin=st.floats(0.1, 4.0),
        norm=st.sampled_from(["l1", "l2"]),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_random_instances(seed, margin, norm):
        _check_random_instance(seed, margin, norm)


class TestFusedLossGradient:
    @pytest.mark.parametrize("norm", ["l1", "l2"])
    def test_custom_vjp_matches_autodiff_of_reference(self, norm):
        """grad(fused kernel loss) == grad(core.transe.margin_loss)."""
        E, R, k, B = 40, 6, 16, 24
        ent, rel, idx = make_inputs(E, R, k, B, seed=7)
        params = {"ent": ent, "rel": rel}
        pos = idx[:, :3]
        neg = jnp.stack([idx[:, 3], idx[:, 1], idx[:, 4]], axis=1)

        g_fused = jax.grad(
            lambda p: ops.transe_margin_loss(
                p, pos, neg, margin=1.0, norm=norm, interpret=True)
        )(params)
        g_ref = jax.grad(
            lambda p: transe.margin_loss(p, pos, neg, margin=1.0, norm=norm)
        )(params)
        np.testing.assert_allclose(
            g_fused["ent"], g_ref["ent"], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            g_fused["rel"], g_ref["rel"], rtol=1e-4, atol=1e-5)

    def test_training_step_with_fused_loss_learns(self):
        E, R, k, B = 30, 4, 8, 16
        ent, rel, idx = make_inputs(E, R, k, B, seed=3)
        params = {"ent": ent, "rel": rel}
        pos = idx[:, :3]
        neg = jnp.stack([idx[:, 3], idx[:, 1], idx[:, 4]], axis=1)

        def loss_fn(p):
            return ops.transe_margin_loss(p, pos, neg, interpret=True)

        l0 = float(loss_fn(params))
        for _ in range(20):
            g = jax.grad(loss_fn)(params)
            params = jax.tree.map(lambda a, b: a - 0.1 * b, params, g)
        assert float(loss_fn(params)) < l0
